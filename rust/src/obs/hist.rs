//! Log2-bucket histograms: O(1) record, bounded memory, mergeable.
//!
//! A value `v` lands in bucket `floor(log2(max(v, 1)))`, so 64 buckets
//! cover the whole `u64` range — recording is a `leading_zeros` plus one
//! add, reading is a single 64-entry scan. Percentiles interpolate
//! linearly inside the winning bucket and are clamped to the observed
//! `[min, max]`, which bounds the error at **one bucket's relative
//! error** (a factor of 2): the estimate always lands in the same
//! power-of-two bucket as the order statistic at the target rank
//! (`sorted[floor(p/100 · (n-1))]`, the lower anchor of the exact
//! linear-interpolated percentile definition in
//! [`crate::util::bench::percentiles`]).
//!
//! Two flavours share this math: the plain [`Log2Hist`] here (single
//! writer, `Clone`, used by `serve::SessionMetrics`) and the atomic
//! [`Histogram`](super::registry::Histogram) in the registry
//! (multi-writer, lock-free).

use crate::util::json::Json;

/// Number of buckets — one per power of two of the `u64` range.
pub const BUCKETS: usize = 64;

/// Bucket index for a value: `floor(log2(max(v, 1)))`.
#[inline]
pub fn bucket_index(v: u64) -> usize {
    (63 - v.max(1).leading_zeros()) as usize
}

/// Inclusive lower bound of a bucket (bucket 0 also holds the value 0).
#[inline]
pub fn bucket_lo(b: usize) -> f64 {
    if b == 0 {
        0.0
    } else {
        2f64.powi(b as i32)
    }
}

/// Exclusive upper bound of a bucket.
#[inline]
pub fn bucket_hi(b: usize) -> f64 {
    2f64.powi(b as i32 + 1)
}

/// Percentile estimate from raw bucket counts: find the bucket holding
/// the target rank (`p/100 * (count-1)`, matching
/// [`crate::util::bench::percentiles`]' rank definition), then
/// interpolate linearly within it. Returns 0 for an empty histogram.
/// Callers clamp to the observed `[min, max]` for the one-bucket error
/// bound.
pub fn percentile_from_buckets(buckets: &[u64; BUCKETS], count: u64, p: f64) -> f64 {
    if count == 0 {
        return 0.0;
    }
    let target = (p / 100.0).clamp(0.0, 1.0) * (count - 1) as f64;
    let mut cum = 0u64;
    for (b, &n) in buckets.iter().enumerate() {
        if n == 0 {
            continue;
        }
        if (cum + n) as f64 > target {
            let frac = ((target - cum as f64 + 0.5) / n as f64).clamp(0.0, 1.0);
            let lo = bucket_lo(b);
            return lo + frac * (bucket_hi(b) - lo);
        }
        cum += n;
    }
    // target == count-1 exactly on the last populated bucket's edge
    bucket_hi(buckets.iter().rposition(|&n| n > 0).unwrap_or(0))
}

/// Single-writer log2 histogram. `Clone` + `Default`, fixed 64-bucket
/// memory whatever the traffic — the replacement for sample-window
/// latency tracking (no per-read copy, no sort).
#[derive(Clone, Debug)]
pub struct Log2Hist {
    buckets: [u64; BUCKETS],
    count: u64,
    sum: u64,
    min: u64,
    max: u64,
}

impl Default for Log2Hist {
    fn default() -> Self {
        Log2Hist { buckets: [0; BUCKETS], count: 0, sum: 0, min: u64::MAX, max: 0 }
    }
}

impl Log2Hist {
    /// Fresh empty histogram.
    pub fn new() -> Log2Hist {
        Log2Hist::default()
    }

    /// Assemble from raw parts — the atomic
    /// [`Histogram`](super::registry::Histogram) snapshots itself into the
    /// plain type through this so one percentile implementation serves
    /// both.
    pub(crate) fn from_raw(
        buckets: [u64; BUCKETS],
        count: u64,
        sum: u64,
        min: u64,
        max: u64,
    ) -> Log2Hist {
        Log2Hist { buckets, count, sum, min, max }
    }

    /// Record one value: one bucket add, O(1), no allocation.
    #[inline]
    pub fn record(&mut self, v: u64) {
        self.buckets[bucket_index(v)] += 1;
        self.count += 1;
        self.sum = self.sum.saturating_add(v);
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Record a (nanosecond) value given as `f64`; negatives clamp to 0.
    #[inline]
    pub fn record_f64(&mut self, v: f64) {
        self.record(v.max(0.0) as u64);
    }

    /// Total values recorded.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Sum of recorded values (saturating).
    pub fn sum(&self) -> u64 {
        self.sum
    }

    /// Mean of recorded values (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Largest recorded value (0 when empty).
    pub fn max(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.max
        }
    }

    /// Percentile estimate, clamped to observed `[min, max]` — within one
    /// bucket's relative error (factor 2) of the sorted-sample order
    /// statistic at the target rank (see the module docs for the exact
    /// bound).
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        percentile_from_buckets(&self.buckets, self.count, p)
            .clamp(self.min as f64, self.max as f64)
    }

    /// Several percentiles in one call (no sample copy, no sort — each is
    /// a 64-entry scan).
    pub fn percentiles(&self, ps: &[f64]) -> Vec<f64> {
        ps.iter().map(|&p| self.percentile(p)).collect()
    }

    /// Merge another histogram into this one (bucket-wise add).
    pub fn merge(&mut self, other: &Log2Hist) {
        for (b, o) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *b += o;
        }
        self.count += other.count;
        self.sum = self.sum.saturating_add(other.sum);
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Summary as JSON: count, sum, mean, p50, p99, max.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::num(self.count as f64)),
            ("sum", Json::num(self.sum as f64)),
            ("mean", Json::num(self.mean())),
            ("p50", Json::num(self.percentile(50.0))),
            ("p99", Json::num(self.percentile(99.0))),
            ("max", Json::num(self.max() as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bench::percentiles;
    use crate::util::check::{default_cases, forall};

    #[test]
    fn bucket_index_is_floor_log2() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 0);
        assert_eq!(bucket_index(2), 1);
        assert_eq!(bucket_index(3), 1);
        assert_eq!(bucket_index(4), 2);
        assert_eq!(bucket_index(1023), 9);
        assert_eq!(bucket_index(1024), 10);
        assert_eq!(bucket_index(u64::MAX), 63);
    }

    #[test]
    fn empty_histogram_reads_zero() {
        let h = Log2Hist::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.percentile(50.0), 0.0);
        assert_eq!(h.percentile(99.0), 0.0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.max(), 0);
    }

    #[test]
    fn single_value_percentiles_are_exact() {
        let mut h = Log2Hist::new();
        h.record(300);
        // clamping to [min, max] makes a one-point histogram exact
        assert_eq!(h.percentile(0.0), 300.0);
        assert_eq!(h.percentile(50.0), 300.0);
        assert_eq!(h.percentile(100.0), 300.0);
    }

    /// The headline accuracy contract: the estimate shares the
    /// power-of-two bucket of the sorted-sample order statistic at the
    /// target rank — within a factor of 2 of `sorted[floor(rank)]`, and
    /// never above twice the exact interpolated percentile
    /// (`util::bench::percentiles`, whose value lies between the two
    /// bracketing order statistics), for arbitrary positive samples.
    #[test]
    fn percentiles_agree_with_sorted_definition_within_one_bucket() {
        forall("hist_vs_sorted", default_cases(), |rng| {
            let n = 1 + rng.gen_range(400);
            let mut h = Log2Hist::new();
            let mut samples = Vec::with_capacity(n);
            for _ in 0..n {
                // spread across many buckets: 1ns .. ~16ms
                let v = 1 + (rng.gen_range_f32(0.0, 24.0).exp2()) as u64;
                h.record(v);
                samples.push(v as f64);
            }
            samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let ps = [50.0, 90.0, 99.0];
            let exact = percentiles(&samples, &ps);
            let est = h.percentiles(&ps);
            for ((p, e), g) in ps.iter().zip(&exact).zip(&est) {
                let anchor = samples[(p / 100.0 * (n - 1) as f64).floor() as usize];
                assert!(
                    *g <= anchor * 2.0 + 1.0 && anchor <= g * 2.0 + 1.0,
                    "rank-{p} order stat {anchor} vs hist {g} drifted past one bucket ({n} samples)"
                );
                assert!(*g <= e * 2.0 + 1.0, "hist {g} above twice the exact percentile {e}");
            }
        });
    }

    #[test]
    fn merge_is_bucketwise_sum() {
        let mut a = Log2Hist::new();
        let mut b = Log2Hist::new();
        let mut whole = Log2Hist::new();
        for v in [3u64, 17, 900, 40_000] {
            a.record(v);
            whole.record(v);
        }
        for v in [1u64, 255, 1_000_000] {
            b.record(v);
            whole.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert_eq!(a.sum(), whole.sum());
        assert_eq!(a.max(), whole.max());
        assert_eq!(a.percentile(50.0), whole.percentile(50.0));
        assert_eq!(a.percentile(99.0), whole.percentile(99.0));
    }

    #[test]
    fn json_summary_has_expected_fields() {
        let mut h = Log2Hist::new();
        for v in 1..=100u64 {
            h.record(v);
        }
        let j = h.to_json();
        assert_eq!(j.get("count").unwrap().as_f64().unwrap(), 100.0);
        assert_eq!(j.get("sum").unwrap().as_f64().unwrap(), 5050.0);
        assert!(j.get("p50").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(j.get("max").unwrap().as_f64().unwrap(), 100.0);
    }
}
