//! Dense row-major `f32` matrix used as the feature/weight container.
//!
//! iSpLib's SpMM is *sparse × dense*: the graph adjacency is sparse (CSR),
//! node features / layer activations are dense. This module provides the
//! dense side: a minimal, allocation-conscious row-major matrix with the
//! handful of BLAS-1/2/3 operations the GNN layers and the autodiff tape
//! need. It is deliberately small — the point of the paper is the *sparse*
//! kernels; dense ops just need to be correct and not embarrassing.

use crate::error::{Error, Result};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Row-major dense matrix of `f32`.
#[derive(Clone, Debug, PartialEq)]
pub struct Dense {
    /// Number of rows.
    pub rows: usize,
    /// Number of columns.
    pub cols: usize,
    /// Row-major storage, `len == rows * cols`.
    pub data: Vec<f32>,
}

impl Dense {
    /// Create a matrix of zeros.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Dense { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Create from a row-major vector; errors if the length is wrong.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Result<Self> {
        if data.len() != rows * cols {
            return Err(Error::ShapeMismatch(format!(
                "Dense::from_vec: {}x{} needs {} elements, got {}",
                rows,
                cols,
                rows * cols,
                data.len()
            )));
        }
        Ok(Dense { rows, cols, data })
    }

    /// Serialize as `{"rows", "cols", "bits"}` with every element stored
    /// as its raw IEEE-754 bit pattern ([`Json::f32_bits`]), so the text
    /// round-trip is bitwise-lossless — checkpoints depend on this.
    pub fn to_json_bits(&self) -> Json {
        Json::obj(vec![
            ("rows", Json::num(self.rows as f64)),
            ("cols", Json::num(self.cols as f64)),
            ("bits", Json::Arr(self.data.iter().map(|&x| Json::f32_bits(x)).collect())),
        ])
    }

    /// Inverse of [`Dense::to_json_bits`]; validates the element count.
    pub fn from_json_bits(json: &Json) -> Result<Dense> {
        let rows = json.get("rows")?.as_usize()?;
        let cols = json.get("cols")?.as_usize()?;
        let bits = json.get("bits")?.as_arr()?;
        let data = bits.iter().map(|b| b.as_f32_bits()).collect::<Result<Vec<f32>>>()?;
        Dense::from_vec(rows, cols, data)
    }

    /// Create with every element drawn from `U(-scale, scale)`.
    pub fn uniform(rows: usize, cols: usize, scale: f32, rng: &mut Rng) -> Self {
        let data = (0..rows * cols).map(|_| rng.gen_range_f32(-scale, scale)).collect();
        Dense { rows, cols, data }
    }

    /// Glorot/Xavier-uniform initialisation, the init GNN papers use.
    pub fn glorot(rows: usize, cols: usize, rng: &mut Rng) -> Self {
        let scale = (6.0f32 / (rows + cols) as f32).sqrt();
        Self::uniform(rows, cols, scale, rng)
    }

    /// Immutable view of row `r`.
    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Mutable view of row `r`.
    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Element access (debug-checked).
    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    /// Element assignment (debug-checked).
    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    /// `self @ other` — register-blocked matmul.
    ///
    /// The same insight as the paper's generated SpMM kernels applies to
    /// the dense projections: keep a fixed-width strip of the output row in
    /// registers across the whole `k` loop instead of re-loading it per
    /// rank-1 update. Column strips of width 16 (one AVX-512 register /
    /// two AVX2) are accumulated in a `[f32; 16]` local; the remainder
    /// falls back to the plain loop.
    pub fn matmul(&self, other: &Dense) -> Result<Dense> {
        let mut out = Dense::zeros(self.rows, other.cols);
        self.matmul_into(other, &mut out)?;
        Ok(out)
    }

    /// [`Dense::matmul`] writing into a caller-provided output of shape
    /// `self.rows × other.cols` (contents are overwritten, like the other
    /// `*_into` siblings — a recycled buffer needs no re-zeroing). Same
    /// arithmetic as `matmul`, bit for bit; only the allocation differs —
    /// this is the seam the workspace-aware tape and the serving forward
    /// path use to keep dense projections allocation-free.
    pub fn matmul_into(&self, other: &Dense, out: &mut Dense) -> Result<()> {
        if self.cols != other.rows {
            return Err(Error::ShapeMismatch(format!(
                "matmul: {}x{} @ {}x{}",
                self.rows, self.cols, other.rows, other.cols
            )));
        }
        if out.rows != self.rows || out.cols != other.cols {
            return Err(Error::ShapeMismatch(format!(
                "matmul_into: out {}x{} for a {}x{} product",
                out.rows, out.cols, self.rows, other.cols
            )));
        }
        const BW: usize = 16;
        let n = other.cols;
        let blocks = n / BW;
        let tail = blocks * BW;
        for i in 0..self.rows {
            let a_row = self.row(i);
            let out_row = out.row_mut(i);
            for blk in 0..blocks {
                let base = blk * BW;
                let mut acc = [0.0f32; BW];
                for (k, &a) in a_row.iter().enumerate() {
                    let b = &other.data[k * n + base..k * n + base + BW];
                    for t in 0..BW {
                        acc[t] += a * b[t];
                    }
                }
                out_row[base..base + BW].copy_from_slice(&acc);
            }
            if tail < n {
                // the tail lanes accumulate, so clear them first — the
                // blocked lanes above already overwrite
                for o in out_row[tail..].iter_mut() {
                    *o = 0.0;
                }
                for (k, &a) in a_row.iter().enumerate() {
                    if a == 0.0 {
                        continue;
                    }
                    let b_row = &other.data[k * n + tail..(k + 1) * n];
                    for (o, &b) in out_row[tail..].iter_mut().zip(b_row.iter()) {
                        *o += a * b;
                    }
                }
            }
        }
        Ok(())
    }

    /// `self^T @ other` without materialising the transpose.
    pub fn t_matmul(&self, other: &Dense) -> Result<Dense> {
        if self.rows != other.rows {
            return Err(Error::ShapeMismatch(format!(
                "t_matmul: ({}x{})^T @ {}x{}",
                self.rows, self.cols, other.rows, other.cols
            )));
        }
        let mut out = Dense::zeros(self.cols, other.cols);
        let n = other.cols;
        for r in 0..self.rows {
            let a_row = self.row(r);
            let b_row = other.row(r);
            for (k, &a) in a_row.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let out_row = &mut out.data[k * n..(k + 1) * n];
                for (o, &b) in out_row.iter_mut().zip(b_row.iter()) {
                    *o += a * b;
                }
            }
        }
        Ok(out)
    }

    /// `self @ other^T` without materialising the transpose.
    pub fn matmul_t(&self, other: &Dense) -> Result<Dense> {
        if self.cols != other.cols {
            return Err(Error::ShapeMismatch(format!(
                "matmul_t: {}x{} @ ({}x{})^T",
                self.rows, self.cols, other.rows, other.cols
            )));
        }
        let mut out = Dense::zeros(self.rows, other.rows);
        for i in 0..self.rows {
            let a_row = self.row(i);
            for j in 0..other.rows {
                let b_row = other.row(j);
                let dot: f32 = a_row.iter().zip(b_row.iter()).map(|(a, b)| a * b).sum();
                out.data[i * other.rows + j] = dot;
            }
        }
        Ok(out)
    }

    /// Element-wise addition (shape-checked).
    pub fn add(&self, other: &Dense) -> Result<Dense> {
        self.zip_with(other, |a, b| a + b)
    }

    /// [`Dense::add`] writing into a caller-provided same-shape output
    /// (contents are overwritten).
    pub fn add_into(&self, other: &Dense, out: &mut Dense) -> Result<()> {
        if self.rows != other.rows || self.cols != other.cols {
            return Err(Error::ShapeMismatch(format!(
                "elementwise: {}x{} vs {}x{}",
                self.rows, self.cols, other.rows, other.cols
            )));
        }
        if out.rows != self.rows || out.cols != self.cols {
            return Err(Error::ShapeMismatch(format!(
                "add_into: out {}x{} vs {}x{}",
                out.rows, out.cols, self.rows, self.cols
            )));
        }
        for ((o, &a), &b) in out.data.iter_mut().zip(self.data.iter()).zip(other.data.iter()) {
            *o = a + b;
        }
        Ok(())
    }

    /// Element-wise subtraction.
    pub fn sub(&self, other: &Dense) -> Result<Dense> {
        self.zip_with(other, |a, b| a - b)
    }

    /// Element-wise multiplication (Hadamard).
    pub fn hadamard(&self, other: &Dense) -> Result<Dense> {
        self.zip_with(other, |a, b| a * b)
    }

    fn zip_with(&self, other: &Dense, f: impl Fn(f32, f32) -> f32) -> Result<Dense> {
        if self.rows != other.rows || self.cols != other.cols {
            return Err(Error::ShapeMismatch(format!(
                "elementwise: {}x{} vs {}x{}",
                self.rows, self.cols, other.rows, other.cols
            )));
        }
        let data = self.data.iter().zip(other.data.iter()).map(|(&a, &b)| f(a, b)).collect();
        Ok(Dense { rows: self.rows, cols: self.cols, data })
    }

    /// In-place `self += alpha * other`.
    pub fn axpy(&mut self, alpha: f32, other: &Dense) -> Result<()> {
        if self.rows != other.rows || self.cols != other.cols {
            return Err(Error::ShapeMismatch(format!(
                "axpy: {}x{} vs {}x{}",
                self.rows, self.cols, other.rows, other.cols
            )));
        }
        for (o, &x) in self.data.iter_mut().zip(other.data.iter()) {
            *o += alpha * x;
        }
        Ok(())
    }

    /// Scale every element by `alpha`.
    pub fn scale(&mut self, alpha: f32) {
        for v in &mut self.data {
            *v *= alpha;
        }
    }

    /// Map every element through `f`, returning a new matrix.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Dense {
        Dense { rows: self.rows, cols: self.cols, data: self.data.iter().map(|&v| f(v)).collect() }
    }

    /// ReLU, the activation used by all the paper's GNNs.
    pub fn relu(&self) -> Dense {
        self.map(|v| v.max(0.0))
    }

    /// In-place ReLU: `self = max(self, 0)` — per element exactly the op
    /// [`Dense::relu_into`] performs, minus the full-matrix write+read of
    /// a second buffer. The plan executor uses this when the relu's input
    /// value dies at the relu itself (in-place slot execution).
    pub fn relu_inplace(&mut self) {
        for v in &mut self.data {
            *v = v.max(0.0);
        }
    }

    /// [`Dense::relu`] writing into a caller-provided same-shape output
    /// (contents are overwritten).
    pub fn relu_into(&self, out: &mut Dense) -> Result<()> {
        if out.rows != self.rows || out.cols != self.cols {
            return Err(Error::ShapeMismatch(format!(
                "relu_into: out {}x{} vs {}x{}",
                out.rows, out.cols, self.rows, self.cols
            )));
        }
        for (o, &v) in out.data.iter_mut().zip(self.data.iter()) {
            *o = v.max(0.0);
        }
        Ok(())
    }

    /// Add a broadcast row vector (bias) to every row.
    pub fn add_row_broadcast(&self, bias: &[f32]) -> Result<Dense> {
        let mut out = self.clone();
        out.add_row_broadcast_inplace(bias)?;
        Ok(out)
    }

    /// [`Dense::add_row_broadcast`] writing into a caller-provided
    /// same-shape output (contents are overwritten).
    pub fn add_row_broadcast_into(&self, bias: &[f32], out: &mut Dense) -> Result<()> {
        Self::check_bias_len(bias, self.cols)?;
        if out.rows != self.rows || out.cols != self.cols {
            return Err(Error::ShapeMismatch(format!(
                "add_row_broadcast_into: out {}x{} vs {}x{}",
                out.rows, out.cols, self.rows, self.cols
            )));
        }
        out.data.copy_from_slice(&self.data);
        out.add_row_broadcast_inplace(bias)
    }

    fn check_bias_len(bias: &[f32], cols: usize) -> Result<()> {
        if bias.len() != cols {
            return Err(Error::ShapeMismatch(format!(
                "bias: len {} vs cols {cols}",
                bias.len()
            )));
        }
        Ok(())
    }

    /// In-place bias broadcast: `self += 1·biasᵀ` — per element exactly
    /// the `+` that [`Dense::add_row_broadcast_into`] applies after its
    /// copy, so the in-place form is bitwise-equal with the copy elided.
    pub fn add_row_broadcast_inplace(&mut self, bias: &[f32]) -> Result<()> {
        Self::check_bias_len(bias, self.cols)?;
        for r in 0..self.rows {
            for (o, &b) in self.row_mut(r).iter_mut().zip(bias.iter()) {
                *o += b;
            }
        }
        Ok(())
    }

    /// In-place elementwise add with `self` as the **left** addend:
    /// `self = self + rhs`, element-for-element the sum
    /// [`Dense::add_into`] computes for `self.add_into(rhs, out)`.
    pub fn add_inplace(&mut self, rhs: &Dense) -> Result<()> {
        if self.rows != rhs.rows || self.cols != rhs.cols {
            return Err(Error::ShapeMismatch(format!(
                "add_inplace: {}x{} vs {}x{}",
                self.rows, self.cols, rhs.rows, rhs.cols
            )));
        }
        for (o, &r) in self.data.iter_mut().zip(rhs.data.iter()) {
            *o += r;
        }
        Ok(())
    }

    /// In-place elementwise add with `self` as the **right** addend:
    /// `self = lhs + self`, element-for-element the sum
    /// [`Dense::add_into`] computes for `lhs.add_into(self, out)` — used
    /// when only the right operand of a plan `Add` dies at the
    /// instruction.
    pub fn radd_inplace(&mut self, lhs: &Dense) -> Result<()> {
        if self.rows != lhs.rows || self.cols != lhs.cols {
            return Err(Error::ShapeMismatch(format!(
                "radd_inplace: {}x{} vs {}x{}",
                lhs.rows, lhs.cols, self.rows, self.cols
            )));
        }
        for (o, &l) in self.data.iter_mut().zip(lhs.data.iter()) {
            *o = l + *o;
        }
        Ok(())
    }

    /// Column-sum → vector of length `cols` (used for bias gradients).
    pub fn col_sum(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.cols];
        for r in 0..self.rows {
            for (o, &v) in out.iter_mut().zip(self.row(r).iter()) {
                *o += v;
            }
        }
        out
    }

    /// Transpose (materialised).
    pub fn transpose(&self) -> Dense {
        let mut out = Dense::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        out
    }

    /// Frobenius norm.
    pub fn frobenius(&self) -> f32 {
        self.data.iter().map(|v| v * v).sum::<f32>().sqrt()
    }

    /// Max absolute difference to another matrix — test helper.
    pub fn max_abs_diff(&self, other: &Dense) -> f32 {
        assert_eq!(self.rows, other.rows);
        assert_eq!(self.cols, other.cols);
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Approximate equality within `tol` — test helper.
    pub fn allclose(&self, other: &Dense, tol: f32) -> bool {
        self.rows == other.rows && self.cols == other.cols && self.max_abs_diff(other) <= tol
    }
}

/// Concatenate matrices column-wise into `out` (shape `rows × Σ cols`,
/// contents overwritten). All inputs must share `rows`.
///
/// This is the micro-batch coalescing primitive: by the identity
/// `Â · [X₁ | … | Xₘ] = [Â·X₁ | … | Â·Xₘ]`, a column-concatenated panel
/// shares one SpMM call — bitwise-equal to per-panel calls because every
/// kernel family accumulates each output element independently along the
/// row's non-zero stream. Both the plan executor
/// ([`crate::plan::execute_inference`]) and the serving batcher build on
/// it.
pub fn concat_cols_into(xs: &[&Dense], out: &mut Dense) -> Result<()> {
    let rows = match xs.first() {
        Some(x) => x.rows,
        None => return Err(Error::Config("concat_cols: empty batch".into())),
    };
    let total: usize = xs.iter().map(|x| x.cols).sum();
    if xs.iter().any(|x| x.rows != rows) {
        return Err(Error::ShapeMismatch("concat_cols: row counts differ".into()));
    }
    if out.rows != rows || out.cols != total {
        return Err(Error::ShapeMismatch(format!(
            "concat_cols: out {}x{} vs {}x{}",
            out.rows, out.cols, rows, total
        )));
    }
    for r in 0..rows {
        let orow = out.row_mut(r);
        let mut base = 0;
        for x in xs {
            orow[base..base + x.cols].copy_from_slice(x.row(r));
            base += x.cols;
        }
    }
    Ok(())
}

/// Allocating form of [`concat_cols_into`].
pub fn concat_cols(xs: &[&Dense]) -> Result<Dense> {
    let rows = xs.first().map(|x| x.rows).unwrap_or(0);
    let total: usize = xs.iter().map(|x| x.cols).sum();
    let mut out = Dense::zeros(rows, total);
    concat_cols_into(xs, &mut out)?;
    Ok(out)
}

/// Split a column-concatenated matrix into caller-provided per-panel
/// matrices (contents overwritten; their widths must sum to `y.cols` and
/// rows must match). The caller owns allocation, so pooled buffers can be
/// handed in.
pub fn split_cols_into(y: &Dense, outs: &mut [Dense]) -> Result<()> {
    let total: usize = outs.iter().map(|o| o.cols).sum();
    if total != y.cols {
        return Err(Error::ShapeMismatch(format!(
            "split_cols: widths sum {} vs cols {}",
            total, y.cols
        )));
    }
    if outs.iter().any(|o| o.rows != y.rows) {
        return Err(Error::ShapeMismatch("split_cols: row counts differ".into()));
    }
    for r in 0..y.rows {
        let yrow = y.row(r);
        let mut base = 0;
        for out in outs.iter_mut() {
            let w = out.cols;
            out.row_mut(r).copy_from_slice(&yrow[base..base + w]);
            base += w;
        }
    }
    Ok(())
}

/// Allocating form of [`split_cols_into`]: split into per-panel matrices
/// of the given widths (`Σ widths == y.cols`).
pub fn split_cols(y: &Dense, widths: &[usize]) -> Result<Vec<Dense>> {
    let mut outs: Vec<Dense> = widths.iter().map(|&w| Dense::zeros(y.rows, w)).collect();
    split_cols_into(y, &mut outs)?;
    Ok(outs)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(rows: usize, cols: usize, v: &[f32]) -> Dense {
        Dense::from_vec(rows, cols, v.to_vec()).unwrap()
    }

    #[test]
    fn zeros_and_from_vec() {
        let z = Dense::zeros(2, 3);
        assert_eq!(z.data, vec![0.0; 6]);
        assert!(Dense::from_vec(2, 2, vec![1.0; 3]).is_err());
    }

    #[test]
    fn json_bits_roundtrip_is_bitwise() {
        let mut rng = Rng::seed_from_u64(11);
        let a = Dense::uniform(4, 3, 1.0, &mut rng);
        let text = a.to_json_bits().pretty();
        let back = Dense::from_json_bits(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.rows, a.rows);
        assert_eq!(back.cols, a.cols);
        let bits: Vec<u32> = a.data.iter().map(|x| x.to_bits()).collect();
        let back_bits: Vec<u32> = back.data.iter().map(|x| x.to_bits()).collect();
        assert_eq!(back_bits, bits);
        // element-count mismatch is rejected
        let bad = Json::parse(r#"{"rows": 2, "cols": 2, "bits": [0, 0, 0]}"#).unwrap();
        assert!(Dense::from_json_bits(&bad).is_err());
    }

    #[test]
    fn matmul_small() {
        let a = m(2, 2, &[1.0, 2.0, 3.0, 4.0]);
        let b = m(2, 2, &[1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b).unwrap();
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_shape_err() {
        let a = Dense::zeros(2, 3);
        let b = Dense::zeros(2, 3);
        assert!(a.matmul(&b).is_err());
    }

    #[test]
    fn t_matmul_matches_explicit_transpose() {
        let mut rng = Rng::seed_from_u64(7);
        let a = Dense::uniform(5, 3, 1.0, &mut rng);
        let b = Dense::uniform(5, 4, 1.0, &mut rng);
        let fast = a.t_matmul(&b).unwrap();
        let slow = a.transpose().matmul(&b).unwrap();
        assert!(fast.allclose(&slow, 1e-5));
    }

    #[test]
    fn matmul_t_matches_explicit_transpose() {
        let mut rng = Rng::seed_from_u64(8);
        let a = Dense::uniform(4, 6, 1.0, &mut rng);
        let b = Dense::uniform(3, 6, 1.0, &mut rng);
        let fast = a.matmul_t(&b).unwrap();
        let slow = a.matmul(&b.transpose()).unwrap();
        assert!(fast.allclose(&slow, 1e-5));
    }

    #[test]
    fn elementwise_ops() {
        let a = m(1, 3, &[1.0, 2.0, 3.0]);
        let b = m(1, 3, &[4.0, 5.0, 6.0]);
        assert_eq!(a.add(&b).unwrap().data, vec![5.0, 7.0, 9.0]);
        assert_eq!(b.sub(&a).unwrap().data, vec![3.0, 3.0, 3.0]);
        assert_eq!(a.hadamard(&b).unwrap().data, vec![4.0, 10.0, 18.0]);
    }

    #[test]
    fn axpy_and_scale() {
        let mut a = m(1, 2, &[1.0, 2.0]);
        let b = m(1, 2, &[10.0, 20.0]);
        a.axpy(0.5, &b).unwrap();
        assert_eq!(a.data, vec![6.0, 12.0]);
        a.scale(2.0);
        assert_eq!(a.data, vec![12.0, 24.0]);
    }

    #[test]
    fn relu_and_map() {
        let a = m(1, 4, &[-1.0, 0.0, 2.0, -3.0]);
        assert_eq!(a.relu().data, vec![0.0, 0.0, 2.0, 0.0]);
        assert_eq!(a.map(|v| v * v).data, vec![1.0, 0.0, 4.0, 9.0]);
    }

    #[test]
    fn bias_and_colsum() {
        let a = m(2, 2, &[1.0, 2.0, 3.0, 4.0]);
        let with_bias = a.add_row_broadcast(&[10.0, 20.0]).unwrap();
        assert_eq!(with_bias.data, vec![11.0, 22.0, 13.0, 24.0]);
        assert_eq!(a.col_sum(), vec![4.0, 6.0]);
        assert!(a.add_row_broadcast(&[1.0]).is_err());
    }

    #[test]
    fn into_variants_match_allocating() {
        let mut rng = Rng::seed_from_u64(21);
        let a = Dense::uniform(5, 7, 1.0, &mut rng);
        let b = Dense::uniform(7, 19, 1.0, &mut rng); // 19 exercises block + tail
        let want = a.matmul(&b).unwrap();
        let mut out = Dense::zeros(5, 19);
        a.matmul_into(&b, &mut out).unwrap();
        assert_eq!(out.data, want.data, "matmul_into must be bitwise-equal");

        let bias: Vec<f32> = (0..19).map(|i| i as f32 * 0.1).collect();
        let want = out.add_row_broadcast(&bias).unwrap();
        let mut biased = Dense::zeros(5, 19);
        out.add_row_broadcast_into(&bias, &mut biased).unwrap();
        assert_eq!(biased.data, want.data);

        let want = biased.relu();
        let mut relued = Dense::zeros(5, 19);
        biased.relu_into(&mut relued).unwrap();
        assert_eq!(relued.data, want.data);

        let want = relued.add(&biased).unwrap();
        let mut summed = Dense::zeros(5, 19);
        relued.add_into(&biased, &mut summed).unwrap();
        assert_eq!(summed.data, want.data);
    }

    /// The in-place dense kernels against their `_into` twins, property-
    /// style: for random shapes and values, `relu_inplace` /
    /// `add_row_broadcast_inplace` / `add_inplace` / `radd_inplace` must
    /// be BITWISE-equal to the copying forms — the plan executor swaps
    /// them in whenever an input value dies at its consuming instruction,
    /// and that swap must never change numerics.
    #[test]
    fn prop_inplace_kernels_bitwise_equal_into_twins() {
        crate::util::check::forall("inplace == _into, bitwise", 64, |rng| {
            let rows = 1 + rng.gen_range(12);
            let cols = 1 + rng.gen_range(17);
            let mk = |rng: &mut Rng| {
                let data =
                    (0..rows * cols).map(|_| rng.gen_range_f32(-2.0, 2.0)).collect::<Vec<_>>();
                Dense { rows, cols, data }
            };
            let a = mk(rng);
            let b = mk(rng);
            let bias: Vec<f32> = (0..cols).map(|_| rng.gen_range_f32(-1.0, 1.0)).collect();

            let mut want = Dense::zeros(rows, cols);
            a.relu_into(&mut want).unwrap();
            let mut got = a.clone();
            got.relu_inplace();
            assert_eq!(got.data, want.data, "relu");

            a.add_row_broadcast_into(&bias, &mut want).unwrap();
            let mut got = a.clone();
            got.add_row_broadcast_inplace(&bias).unwrap();
            assert_eq!(got.data, want.data, "bias");

            a.add_into(&b, &mut want).unwrap();
            let mut got = a.clone();
            got.add_inplace(&b).unwrap();
            assert_eq!(got.data, want.data, "add (lhs accumulator)");
            let mut got = b.clone();
            got.radd_inplace(&a).unwrap();
            assert_eq!(got.data, want.data, "add (rhs accumulator)");
        });
    }

    #[test]
    fn inplace_kernels_reject_bad_shapes() {
        let mut a = Dense::zeros(2, 3);
        assert!(a.add_row_broadcast_inplace(&[0.0; 2]).is_err());
        assert!(a.add_inplace(&Dense::zeros(3, 2)).is_err());
        assert!(a.radd_inplace(&Dense::zeros(2, 2)).is_err());
        assert!(a.add_inplace(&Dense::zeros(2, 3)).is_ok());
    }

    #[test]
    fn matmul_into_overwrites_dirty_buffer() {
        let mut rng = Rng::seed_from_u64(22);
        let a = Dense::uniform(4, 6, 1.0, &mut rng);
        let b = Dense::uniform(6, 19, 1.0, &mut rng); // tail lanes present
        let want = a.matmul(&b).unwrap();
        let mut out = Dense::from_vec(4, 19, vec![7.5; 4 * 19]).unwrap();
        // same call twice into the same dirty buffer: still exact
        a.matmul_into(&b, &mut out).unwrap();
        a.matmul_into(&b, &mut out).unwrap();
        assert_eq!(out.data, want.data, "matmul_into must not depend on prior contents");
    }

    #[test]
    fn into_variants_reject_bad_shapes() {
        let a = Dense::zeros(2, 3);
        let b = Dense::zeros(3, 4);
        assert!(a.matmul_into(&b, &mut Dense::zeros(2, 5)).is_err());
        assert!(a.matmul_into(&Dense::zeros(2, 4), &mut Dense::zeros(2, 4)).is_err());
        assert!(a.add_row_broadcast_into(&[0.0; 2], &mut Dense::zeros(2, 3)).is_err());
        assert!(a.add_row_broadcast_into(&[0.0; 3], &mut Dense::zeros(3, 3)).is_err());
        assert!(a.relu_into(&mut Dense::zeros(3, 2)).is_err());
        assert!(a.add_into(&Dense::zeros(2, 3), &mut Dense::zeros(2, 2)).is_err());
        assert!(a.add_into(&Dense::zeros(2, 2), &mut Dense::zeros(2, 3)).is_err());
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::seed_from_u64(9);
        let a = Dense::uniform(3, 5, 1.0, &mut rng);
        assert!(a.transpose().transpose().allclose(&a, 0.0));
    }

    #[test]
    fn glorot_scale_bound() {
        let mut rng = Rng::seed_from_u64(10);
        let a = Dense::glorot(100, 50, &mut rng);
        let bound = (6.0f32 / 150.0).sqrt();
        assert!(a.data.iter().all(|v| v.abs() <= bound));
        // and it isn't all zeros
        assert!(a.frobenius() > 0.0);
    }

    #[test]
    fn concat_split_roundtrip() {
        let mut rng = Rng::seed_from_u64(31);
        let a = Dense::uniform(4, 3, 1.0, &mut rng);
        let b = Dense::uniform(4, 5, 1.0, &mut rng);
        let c = Dense::uniform(4, 1, 1.0, &mut rng);
        let packed = concat_cols(&[&a, &b, &c]).unwrap();
        assert_eq!(packed.rows, 4);
        assert_eq!(packed.cols, 9);
        assert_eq!(packed.get(2, 0), a.get(2, 0));
        assert_eq!(packed.get(2, 3), b.get(2, 0));
        assert_eq!(packed.get(2, 8), c.get(2, 0));
        let back = split_cols(&packed, &[3, 5, 1]).unwrap();
        assert_eq!(back[0].data, a.data);
        assert_eq!(back[1].data, b.data);
        assert_eq!(back[2].data, c.data);
    }

    #[test]
    fn concat_rejects_bad_inputs() {
        let a = Dense::zeros(4, 3);
        let b = Dense::zeros(5, 3);
        assert!(concat_cols(&[&a, &b]).is_err()); // row mismatch
        assert!(concat_cols(&[]).is_err()); // empty batch
        let mut out = Dense::zeros(4, 5); // wrong total width
        assert!(concat_cols_into(&[&a], &mut out).is_err());
    }

    #[test]
    fn split_rejects_bad_widths() {
        let y = Dense::zeros(3, 6);
        assert!(split_cols(&y, &[3, 2]).is_err());
        assert!(split_cols(&y, &[3, 3]).is_ok());
    }
}
