//! iSpLib CLI — the leader entrypoint.
//!
//! ```text
//! isplib probe                       # hardware probe + kernel geometry
//! isplib datasets [--scale N]        # regenerate Table 1
//! isplib tune [--profiles P] [...]   # regenerate Figure 2 tuning graphs
//! isplib train --model gcn --dataset reddit --backend isplib [...]
//! isplib bench [...]                 # regenerate the Figure 3 grid
//! ```

use isplib::autotune::{render_ascii_chart, HardwareProfile};
use isplib::coordinator::{
    figure2_sweep, figure3_grid, figure3_to_json, headline_speedups, render_figure3,
    render_table1, table1_rows, ExperimentConfig,
};
use isplib::data::{karate_club, paper_specs, spec_by_name, DatasetSpec};
use isplib::error::{Error, Result};
use isplib::gnn::GnnModel;
use isplib::train::{Backend, TrainConfig, Trainer};
use isplib::util::cli::Args;
use isplib::util::json::Json;

const USAGE: &str = "\
isplib — auto-tuned sparse operations for GNN training (iSpLib reproduction)

USAGE: isplib <COMMAND> [FLAGS]

COMMANDS:
  probe      Probe the host (and show the paper's two modelled CPUs)
  datasets   Regenerate Table 1     [--scale 256] [--seed 7]
  tune       Regenerate Figure 2    [--profiles intel-skylake,amd-epyc]
             [--datasets all] [--ks 16,32,64,128,256,512,1024]
             [--scale 256] [--json]
  train      Train one cell         [--model gcn] [--dataset karate]
             [--backend isplib] [--epochs 30] [--hidden 32] [--scale 256]
             [--artifacts artifacts] [--json]
  bench      Regenerate Figure 3    [--models gcn,sage-sum,gin]
             [--datasets all] [--frameworks all] [--epochs 10]
             [--hidden 32] [--scale 256] [--json]

Models:     gcn | sage-sum | sage-mean | gin
Backends:   isplib | pt2 | pt1 | pt2-mp | dense | hlo
Datasets:   reddit | reddit2 | ogbn-mag | ogbn-products | amazon |
            ogbn-protein | karate (train only)
";

fn main() {
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = run(args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn run(args: Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("probe") => probe(),
        Some("datasets") => datasets(&args),
        Some("tune") => tune(&args),
        Some("train") => train(&args),
        Some("bench") => bench(&args),
        Some("help") | None => {
            println!("{USAGE}");
            Ok(())
        }
        Some(other) => Err(Error::Config(format!("unknown command '{other}'\n\n{USAGE}"))),
    }
}

fn probe() -> Result<()> {
    for name in ["host", "intel-skylake", "amd-epyc"] {
        let p = HardwareProfile::named(name)?;
        println!(
            "{:<14} simd={:?} vlen_f32={} vregs={} cores={} kbs={:?} kts={:?} best_kb={}",
            p.name,
            p.simd,
            p.vlen(),
            p.vector_registers,
            p.cores,
            p.candidate_kbs(),
            p.candidate_kts(),
            p.predicted_best_kb()
        );
    }
    Ok(())
}

fn datasets(args: &Args) -> Result<()> {
    let cfg = ExperimentConfig {
        scale: args.get_parse("scale", 256usize)?,
        seed: args.get_parse("seed", 7u64)?,
        ..ExperimentConfig::default()
    };
    let rows = table1_rows(&cfg)?;
    print!("{}", render_table1(&rows));
    Ok(())
}

fn tune(args: &Args) -> Result<()> {
    let cfg = ExperimentConfig {
        scale: args.get_parse("scale", 256usize)?,
        ..ExperimentConfig::default()
    };
    let specs = parse_datasets(&args.get("datasets", "all"))?;
    let profiles_arg = args.get("profiles", "intel-skylake,amd-epyc");
    let profiles: Vec<&str> = profiles_arg.split(',').collect();
    let ks_arg = args.get("ks", "16,32,64,128,256,512,1024");
    let ks = parse_usize_list(&ks_arg)?;
    let reports = figure2_sweep(&cfg, &specs, &profiles, &ks)?;
    if args.has("json") {
        let arr = Json::Arr(reports.iter().map(|r| r.to_json()).collect());
        println!("{}", arr.pretty());
    } else {
        for r in &reports {
            print!("{}", render_ascii_chart(r));
        }
    }
    Ok(())
}

fn train(args: &Args) -> Result<()> {
    let model = GnnModel::parse(&args.get("model", "gcn"))?;
    let backend = Backend::parse(&args.get("backend", "isplib"))?;
    let dataset_name = args.get("dataset", "karate");
    let scale = args.get_parse("scale", 256usize)?;
    let ds = if dataset_name == "karate" {
        karate_club()
    } else {
        spec_by_name(&dataset_name)
            .ok_or_else(|| Error::UnknownName(format!("dataset '{dataset_name}'")))?
            .instantiate(scale, 7)?
    };
    let cfg = TrainConfig {
        epochs: args.get_parse("epochs", 30usize)?,
        hidden: args.get_parse("hidden", 32usize)?,
        artifacts_dir: Some(args.get("artifacts", "artifacts").into()),
        ..TrainConfig::default()
    };
    let mut trainer = Trainer::new(model, backend, cfg, &ds)?;
    let report = trainer.fit(&ds)?;
    if args.has("json") {
        println!("{}", report.to_json().pretty());
    } else {
        println!(
            "model={} backend={} dataset={} epochs={} avg_epoch={:.6}s setup={:.3}s \
             final_loss={:.4} train_acc={:.3} test_acc={:.3}",
            report.model,
            report.backend,
            report.dataset,
            report.epoch_secs.len(),
            report.avg_epoch_secs(),
            report.setup_secs,
            report.final_loss,
            report.train_acc,
            report.test_acc
        );
    }
    Ok(())
}

fn bench(args: &Args) -> Result<()> {
    let cfg = ExperimentConfig {
        scale: args.get_parse("scale", 256usize)?,
        epochs: args.get_parse("epochs", 10usize)?,
        hidden: args.get_parse("hidden", 32usize)?,
        ..ExperimentConfig::default()
    };
    let models = parse_models(&args.get("models", "gcn,sage-sum,gin"))?;
    let specs = parse_datasets(&args.get("datasets", "all"))?;
    let backends = parse_backends(&args.get("frameworks", "all"))?;
    let cells = figure3_grid(&cfg, &models, &specs, &backends)?;
    if args.has("json") {
        println!("{}", figure3_to_json(&cells).pretty());
    } else {
        print!("{}", render_figure3(&cells));
        println!("\nheadline speedups vs PT2 (max over datasets):");
        for (model, speedup) in headline_speedups(&cells) {
            println!("  {model}: {speedup:.1}x");
        }
    }
    Ok(())
}

fn parse_usize_list(s: &str) -> Result<Vec<usize>> {
    s.split(',')
        .map(|t| {
            t.trim()
                .parse()
                .map_err(|_| Error::Config(format!("cannot parse '{t}' as a number")))
        })
        .collect()
}

fn parse_models(s: &str) -> Result<Vec<GnnModel>> {
    if s == "all" {
        return Ok(GnnModel::ALL.to_vec());
    }
    s.split(',').map(|m| GnnModel::parse(m.trim())).collect()
}

fn parse_datasets(s: &str) -> Result<Vec<DatasetSpec>> {
    if s == "all" {
        return Ok(paper_specs());
    }
    s.split(',')
        .map(|name| {
            spec_by_name(name.trim())
                .ok_or_else(|| Error::UnknownName(format!("dataset '{name}'")))
        })
        .collect()
}

fn parse_backends(s: &str) -> Result<Vec<Backend>> {
    if s == "all" {
        return Ok(Backend::NATIVE_ALL.to_vec());
    }
    s.split(',').map(|b| Backend::parse(b.trim())).collect()
}
