//! iSpLib CLI — the leader entrypoint.
//!
//! ```text
//! isplib probe                       # hardware probe + kernel geometry
//! isplib datasets [--scale N]        # regenerate Table 1
//! isplib tune [--profiles P] [...]   # regenerate Figure 2 tuning graphs
//! isplib train --model gcn --dataset reddit --backend isplib [...]
//! isplib bench [...]                 # regenerate the Figure 3 grid
//! isplib serve-bench [...]           # multi-graph serving bench → BENCH_serving.json
//! ```

use isplib::autotune::{render_ascii_chart, HardwareProfile};
use isplib::coordinator::{
    figure2_sweep, figure3_grid, figure3_to_json, headline_speedups, render_figure3,
    render_table1, table1_rows, ExperimentConfig,
};
use isplib::data::{karate_club, paper_specs, spec_by_name, DatasetSpec};
use isplib::error::{Error, Result};
use isplib::gnn::GnnModel;
use isplib::train::{Backend, TrainConfig, Trainer};
use isplib::util::cli::Args;
use isplib::util::json::Json;

const USAGE: &str = "\
isplib — auto-tuned sparse operations for GNN training (iSpLib reproduction)

USAGE: isplib <COMMAND> [FLAGS]

COMMANDS:
  probe      Probe the host (and show the paper's two modelled CPUs)
  datasets   Regenerate Table 1     [--scale 256] [--seed 7]
  tune       Regenerate Figure 2    [--profiles intel-skylake,amd-epyc]
             [--datasets all] [--ks 16,32,64,128,256,512,1024]
             [--scale 256] [--json]
  train      Train one cell         [--model gcn] [--dataset karate]
             [--backend isplib] [--epochs 30] [--hidden 32] [--scale 256]
             [--artifacts artifacts] [--json]
             --checkpoint-dir persists a crash-safe training checkpoint
             (atomic write, checksummed, .bak generation) at the end of
             the run — and every N epochs with --checkpoint-every N.
             --resume loads it and continues to --epochs; the resumed
             trajectory is bitwise-identical to an uninterrupted run.
             [--checkpoint-dir ckpt] [--checkpoint-every 0] [--resume]
  bench      Regenerate Figure 3    [--models gcn,sage-sum,gin]
             [--datasets all] [--frameworks all] [--epochs 10]
             [--hidden 32] [--scale 256] [--json]
  serve-bench  Batched multi-graph inference serving bench: trains one
             model per dataset, registers warm-started sessions sharing
             one worker pool + kernel workspace, drives a skewed load
             through the DRR scheduler, verifies batched == per-request
             bitwise and that inference leaves the backprop cache
             untouched, and emits BENCH_serving.json.
             [--datasets ogbn-protein,reddit] [--models gcn,sage-sum]
             [--requests 24] [--skew 4] [--max-batch 8] [--quantum 4]
             [--max-wait-ms 5] [--threads 2] [--session-threads 0]
             [--epochs 3] [--hidden 16]
             [--scale 2048] [--out BENCH_serving.json] [--json]
             --churn drives a live-mutation phase on the flooded session:
             edge deltas and model hot-swaps interleave with serving, and
             every completion is verified bitwise against its
             admission-stamp reference (infer_at). Results land in the
             JSON under \"churn\".
             [--churn] [--delta-rate 8] [--swap-every 3] [--staleness 0.25]
             --restart persists the session manifest + tuning DB through
             the durable layer, tears the server down, rebuilds it from
             the two files, and verifies restored sessions serve bitwise-
             equal outputs with warm-starts replayed and zero
             re-measurement. Results land in the JSON under \"restart\".
             [--restart] [--manifest serve_manifest.json]
             [--tuning-db serve_tunedb.json]

GLOBAL FLAGS:
  --trace <path>   Write a Perfetto/Chrome trace-event JSON of the whole
                   run to <path> on exit (load at ui.perfetto.dev). Implies
                   metrics collection. train and serve-bench always collect
                   metrics and dump the registry snapshot on exit
                   (serve-bench embeds it in BENCH_serving.json as \"obs\").

Models:     gcn | sage-sum | sage-mean | gin
Backends:   isplib | pt2 | pt1 | pt2-mp | dense | hlo
Datasets:   reddit | reddit2 | ogbn-mag | ogbn-products | amazon |
            ogbn-protein | karate (train only)
";

fn main() {
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = run(args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn run(args: Args) -> Result<()> {
    // --trace works on any subcommand: turn span tracing on before
    // dispatch, write the Perfetto/Chrome trace-event JSON after — even on
    // error, since a trace of a failing run is exactly when you want one.
    let trace_path = args.flags.get("trace").cloned();
    if trace_path.is_some() {
        isplib::obs::set_tracing(true);
        isplib::obs::set_metrics(true);
    }
    let out = match args.subcommand.as_deref() {
        Some("probe") => probe(),
        Some("datasets") => datasets(&args),
        Some("tune") => tune(&args),
        Some("train") => train(&args),
        Some("bench") => bench(&args),
        Some("serve-bench") => serve_bench(&args),
        Some("help") | None => {
            println!("{USAGE}");
            Ok(())
        }
        Some(other) => Err(Error::Config(format!("unknown command '{other}'\n\n{USAGE}"))),
    };
    if let Some(path) = &trace_path {
        isplib::obs::write_trace(std::path::Path::new(path))?;
        eprintln!("trace: wrote {path} ({} events)", isplib::obs::trace_event_count());
    }
    out
}

fn probe() -> Result<()> {
    for name in ["host", "intel-skylake", "amd-epyc"] {
        let p = HardwareProfile::named(name)?;
        println!(
            "{:<14} simd={:?} vlen_f32={} vregs={} cores={} kbs={:?} kts={:?} sell={:?} best_kb={}",
            p.name,
            p.simd,
            p.vlen(),
            p.vector_registers,
            p.cores,
            p.candidate_kbs(),
            p.candidate_kts(),
            p.candidate_sell_params(),
            p.predicted_best_kb()
        );
    }
    Ok(())
}

fn datasets(args: &Args) -> Result<()> {
    let cfg = ExperimentConfig {
        scale: args.get_parse("scale", 256usize)?,
        seed: args.get_parse("seed", 7u64)?,
        ..ExperimentConfig::default()
    };
    let rows = table1_rows(&cfg)?;
    print!("{}", render_table1(&rows));
    Ok(())
}

fn tune(args: &Args) -> Result<()> {
    let cfg = ExperimentConfig {
        scale: args.get_parse("scale", 256usize)?,
        ..ExperimentConfig::default()
    };
    let specs = parse_datasets(&args.get("datasets", "all"))?;
    let profiles_arg = args.get("profiles", "intel-skylake,amd-epyc");
    let profiles: Vec<&str> = profiles_arg.split(',').collect();
    let ks_arg = args.get("ks", "16,32,64,128,256,512,1024");
    let ks = parse_usize_list(&ks_arg)?;
    let reports = figure2_sweep(&cfg, &specs, &profiles, &ks)?;
    if args.has("json") {
        let arr = Json::Arr(reports.iter().map(|r| r.to_json()).collect());
        println!("{}", arr.pretty());
    } else {
        for r in &reports {
            print!("{}", render_ascii_chart(r));
        }
    }
    Ok(())
}

fn train(args: &Args) -> Result<()> {
    let model = GnnModel::parse(&args.get("model", "gcn"))?;
    let backend = Backend::parse(&args.get("backend", "isplib"))?;
    let dataset_name = args.get("dataset", "karate");
    let scale = args.get_parse("scale", 256usize)?;
    let ds = if dataset_name == "karate" {
        karate_club()
    } else {
        spec_by_name(&dataset_name)
            .ok_or_else(|| Error::UnknownName(format!("dataset '{dataset_name}'")))?
            .instantiate(scale, 7)?
    };
    let cfg = TrainConfig {
        epochs: args.get_parse("epochs", 30usize)?,
        hidden: args.get_parse("hidden", 32usize)?,
        artifacts_dir: Some(args.get("artifacts", "artifacts").into()),
        ..TrainConfig::default()
    };
    let ckpt_dir = args.flags.get("checkpoint-dir").map(std::path::PathBuf::from);
    let ckpt_every = args.get_parse("checkpoint-every", 0usize)?;
    let resume = args.has("resume");
    if (resume || ckpt_every > 0) && ckpt_dir.is_none() {
        return Err(Error::Config(
            "--resume and --checkpoint-every require --checkpoint-dir".into(),
        ));
    }
    // train always collects metrics: fit() publishes cache/workspace
    // counters at exit and the registry snapshot is dumped below
    isplib::obs::set_metrics(true);
    let mut trainer = Trainer::new(model, backend, cfg, &ds)?;
    let report = match &ckpt_dir {
        Some(dir) => {
            if resume && trainer.resume(dir)? {
                eprintln!(
                    "resumed from {} at epoch {}",
                    dir.display(),
                    trainer.epochs_run()
                );
            }
            trainer.fit_with_checkpoints(&ds, Some(dir.as_path()), ckpt_every)?
        }
        None => trainer.fit(&ds)?,
    };
    if args.has("json") {
        let mut json = report.to_json();
        if let Json::Obj(m) = &mut json {
            m.insert("obs".to_string(), isplib::obs::snapshot());
        }
        println!("{}", json.pretty());
    } else {
        println!(
            "model={} backend={} dataset={} epochs={} avg_epoch={:.6}s setup={:.3}s \
             final_loss={:.4} train_acc={:.3} test_acc={:.3}",
            report.model,
            report.backend,
            report.dataset,
            report.epoch_secs.len(),
            report.avg_epoch_secs(),
            report.setup_secs,
            report.final_loss,
            report.train_acc,
            report.test_acc
        );
        println!("obs snapshot:\n{}", isplib::obs::snapshot().pretty());
    }
    Ok(())
}

fn bench(args: &Args) -> Result<()> {
    let cfg = ExperimentConfig {
        scale: args.get_parse("scale", 256usize)?,
        epochs: args.get_parse("epochs", 10usize)?,
        hidden: args.get_parse("hidden", 32usize)?,
        ..ExperimentConfig::default()
    };
    let models = parse_models(&args.get("models", "gcn,sage-sum,gin"))?;
    let specs = parse_datasets(&args.get("datasets", "all"))?;
    let backends = parse_backends(&args.get("frameworks", "all"))?;
    let cells = figure3_grid(&cfg, &models, &specs, &backends)?;
    if args.has("json") {
        println!("{}", figure3_to_json(&cells).pretty());
    } else {
        print!("{}", render_figure3(&cells));
        println!("\nheadline speedups vs PT2 (max over datasets):");
        for (model, speedup) in headline_speedups(&cells) {
            println!("  {model}: {speedup:.1}x");
        }
    }
    Ok(())
}

/// The serving acceptance bench: ≥2 graph sessions over one pool/workspace,
/// skewed load through the DRR scheduler, bitwise + cache-untouched checks,
/// `BENCH_serving.json` out. Errors (non-zero exit) if any check fails.
fn serve_bench(args: &Args) -> Result<()> {
    use std::time::Instant;

    use isplib::autotune::{KernelRegistry, TuneConfig, Tuner, TuningDb};
    use isplib::dense::Dense;
    use isplib::gnn::ModelParams;
    use isplib::kernels::Semiring;
    use isplib::serve::{InferenceServer, ServeConfig};
    use isplib::util::parallel::WorkerPool;
    use isplib::util::rng::Rng;

    // the bench always collects metrics: the registry snapshot (per-op
    // timing aggregates, pool utilization, serve gauges) lands in
    // BENCH_serving.json under "obs"
    isplib::obs::set_metrics(true);

    let scale = args.get_parse("scale", 2048usize)?;
    let hidden = args.get_parse("hidden", 16usize)?;
    let epochs = args.get_parse("epochs", 3usize)?;
    let requests = args.get_parse("requests", 24usize)?;
    let skew = args.get_parse("skew", 4usize)?.max(1);
    // --overload: drive the fault-isolation path instead of the happy
    // path — a tight per-session queue cap plus a completion deadline, so
    // the flooding session sheds at its own door (Overloaded rejections)
    // and stale queued work sheds before batch formation
    // (DeadlineExceeded). Counters + p99-under-overload land in the JSON.
    let overload = args.has("overload");
    let max_batch = args.get_parse("max-batch", 8usize)?;
    let cfg = ServeConfig {
        max_batch,
        quantum: args.get_parse("quantum", 4usize)?,
        threads: args.get_parse("threads", 2usize)?,
        // per-session kernel budget (0 inherits --threads); 1 pins every
        // session inline, off the shared pool
        session_threads: args.get_parse("session-threads", 0usize)?,
        // arrival-driven batching deadline: the bench drains through
        // run_ready, so underfull tail batches are held until this expires
        max_wait: std::time::Duration::from_millis(args.get_parse("max-wait-ms", 5u64)?),
        queue_cap: if overload {
            args.get_parse("queue-cap", max_batch.max(1) * 2)?
        } else {
            args.get_parse("queue-cap", 0usize)?
        },
        default_deadline: std::time::Duration::from_millis(if overload {
            args.get_parse("deadline-ms", 50u64)?
        } else {
            args.get_parse("deadline-ms", 0u64)?
        }),
        // staleness threshold of the delta re-tuning policy (only
        // consulted by the --churn phase's apply_delta calls)
        staleness: args.get_parse("staleness", 0.25f64)?,
        ..ServeConfig::default()
    };
    let out_path = args.get("out", "BENCH_serving.json");
    let datasets_arg = args.get("datasets", "ogbn-protein,reddit");
    let names: Vec<&str> = datasets_arg.split(',').map(|s| s.trim()).collect();
    if names.len() < 2 {
        return Err(Error::Config("serve-bench needs ≥ 2 sessions (--datasets a,b)".into()));
    }
    let model_list = parse_models(&args.get("models", "gcn,sage-sum"))?;

    // --- train one model per dataset: the sessions' frozen params --------
    let mut trained = Vec::new();
    for (i, name) in names.iter().enumerate() {
        let ds = if *name == "karate" {
            karate_club()
        } else {
            spec_by_name(name)
                .ok_or_else(|| Error::UnknownName(format!("dataset '{name}'")))?
                .instantiate(scale, 7)?
        };
        let model = model_list[i % model_list.len()];
        let tcfg = TrainConfig {
            epochs,
            hidden,
            threads: cfg.threads,
            skip_tuning: true,
            ..TrainConfig::default()
        };
        let mut trainer = Trainer::new(model, Backend::NativeTuned, tcfg, &ds)?;
        trainer.fit(&ds)?;
        trained.push((ds, model, trainer));
    }

    // --- tune at "training time", persisting decisions into a DB. Cover
    // the coalesced batch widths too: that is what the sessions warm-start
    // and what batched inference actually runs SpMM at. ------------------
    let tuner = Tuner::with_config(
        HardwareProfile::named("host")?,
        TuneConfig { ks: vec![], reps: 1, warmup: 0, threads: cfg.threads },
    );
    let registry = KernelRegistry::global();
    registry.set_patched(true);
    let mut db = TuningDb::default();
    for (ds, model, _) in &trained {
        let dims = ModelParams { in_dim: ds.feature_dim(), hidden, classes: ds.num_classes };
        let a = model.norm_kind().apply(&ds.adj)?;
        // tune exactly the widths the lowered plan will run SpMM at —
        // per-request and coalesced. Fusable widths skip the spmm-only
        // sweep: the joint format × fusion search is the whole decision
        // there, so sessions warm-start ONE (format, fuse) choice per
        // shape without a redundant plain pass.
        let plan = model.lower(dims, model.norm_kind());
        let fusable = plan.fusable_spmm_widths();
        for k in plan.spmm_shapes_batched(cfg.max_batch) {
            if fusable.contains(&k) {
                continue;
            }
            tuner.tune(&ds.name, &a, k, registry, &mut db)?;
        }
        for k in fusable {
            tuner.tune_fused_relu(&ds.name, &a, k, registry, &mut db)?;
        }
    }

    // --- register sessions: warm-started, no serving-time measurement ----
    let mut server = InferenceServer::new(cfg);
    let mut sids = Vec::new();
    for (ds, model, trainer) in &trained {
        let dims = ModelParams { in_dim: ds.feature_dim(), hidden, classes: ds.num_classes };
        let sid = server.register_session(
            &ds.name,
            *model,
            dims,
            trainer.export_params()?,
            &ds.adj,
            Some((&tuner, &db)),
        )?;
        sids.push(sid);
    }

    // --- offered load: session 0 floods skew×, everyone else 1×. Under
    // --overload the flood deliberately exceeds the queue cap: rejected
    // submits are the admission-control path working, not a bench
    // failure — they are counted, not retried. ---------------------------
    let mut rng = Rng::seed_from_u64(17);
    let mut offered = vec![0usize; sids.len()];
    let mut accepted = vec![0usize; sids.len()];
    let mut rejected_submits = 0usize;
    for (i, &sid) in sids.iter().enumerate() {
        let count = if i == 0 { requests * skew } else { requests };
        let (n, f) = {
            let s = server.session(sid)?;
            (s.nodes(), s.dims.in_dim)
        };
        for _ in 0..count {
            match server.submit(sid, Dense::uniform(n, f, 1.0, &mut rng)) {
                Ok(_) => accepted[i] += 1,
                Err(e @ Error::Overloaded { .. }) if overload => {
                    debug_assert!(e.is_retryable());
                    rejected_submits += 1;
                }
                Err(e) => return Err(e),
            }
        }
        offered[i] = count;
    }
    let total: usize = accepted.iter().sum();

    let cache_before: Vec<_> = trained.iter().map(|(_, _, t)| t.cache().stats()).collect();
    let jobs_before = WorkerPool::global().jobs_executed();
    // Drain through the arrival-driven scheduler: run_ready serves full
    // batches immediately and holds underfull tails until --max-wait-ms
    // expires — the skewed backlog's tail batch is exactly the
    // lone-request case the deadline exists for, so the knob is exercised
    // end-to-end on every bench run.
    let t0 = Instant::now();
    let mut done = Vec::new();
    while server.pending() > 0 {
        done.extend(server.run_ready()?);
        if server.pending() > 0 {
            std::thread::sleep(std::time::Duration::from_micros(200));
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let pool_jobs = WorkerPool::global().jobs_executed() - jobs_before;

    // --- acceptance checks ------------------------------------------------
    // every accepted request must terminate with a typed outcome — served
    // logits, or (under --overload) DeadlineExceeded shed. Nothing may
    // vanish, and nothing may fail untyped.
    if done.len() != total {
        return Err(Error::Runtime(format!(
            "serve-bench: {} of {total} accepted requests completed",
            done.len()
        )));
    }
    let served = done.iter().filter(|c| c.output().is_some()).count();
    let shed = done
        .iter()
        .filter(|c| matches!(c.outcome, Err(Error::DeadlineExceeded(_))))
        .count();
    if served + shed != total {
        return Err(Error::Runtime(format!(
            "serve-bench: {} served + {shed} shed ≠ {total} — some request \
             terminated with an unexpected outcome",
            served
        )));
    }
    if !overload && served != total {
        return Err(Error::Runtime(format!(
            "serve-bench: only {served} of {total} requests served outside --overload"
        )));
    }
    let mut checked = 0usize;
    for &sid in &sids {
        for c in done.iter().filter(|c| c.session == sid && c.output().is_some()).take(4) {
            let solo = server.infer_now(sid, &c.features)?;
            if solo.data != c.output().unwrap().data {
                return Err(Error::Runtime(format!(
                    "serve-bench: batched output for request {} diverged from per-request inference",
                    c.id
                )));
            }
            checked += 1;
        }
    }
    let cache_after: Vec<_> = trained.iter().map(|(_, _, t)| t.cache().stats()).collect();
    if cache_before != cache_after {
        return Err(Error::Runtime(
            "serve-bench: the inference path touched a BackpropCache".into(),
        ));
    }

    // --- report -----------------------------------------------------------
    let wstats = server.workspace().stats();
    let spread = server.p99_spread();
    println!(
        "serve-bench: {} sessions sharing 1 pool/workspace; {} requests ({checked} verified \
         bitwise vs per-request), {wall:.3}s wall, {pool_jobs} pool jobs, cache untouched",
        sids.len(),
        done.len()
    );
    let mut sessions_json = Vec::new();
    for (i, &sid) in sids.iter().enumerate() {
        let s = server.session(sid)?;
        let m = server.metrics(sid)?;
        let (p50_ns, p99_ns) = m.latency_percentiles();
        let kernels: Vec<String> = s
            .plan()
            .spmm_shapes()
            .into_iter()
            .map(|k| format!("K{k}:{}", registry.resolve(&s.name, k, Semiring::Sum).label()))
            .collect();
        println!(
            "  {:<16} model={:<9} nodes={:<6} requests={:<4} batches={:<3} occupancy={:.2} \
             p50={:.1}µs p99={:.1}µs warm={} fused_ops={} kernels=[{}]",
            s.name,
            s.model.name(),
            s.nodes(),
            m.requests,
            m.batches,
            m.occupancy(),
            p50_ns / 1e3,
            p99_ns / 1e3,
            s.warm_started,
            s.fused_ops(),
            kernels.join(" ")
        );
        sessions_json.push(Json::obj(vec![
            ("name", Json::str(&s.name)),
            ("model", Json::str(s.model.name())),
            ("nodes", Json::num(s.nodes() as f64)),
            ("nnz", Json::num(s.nnz() as f64)),
            ("offered", Json::num(offered[i] as f64)),
            ("warm_started", Json::num(s.warm_started as f64)),
            ("preconverted_formats", Json::num(s.preconverted as f64)),
            ("fused_ops", Json::num(s.fused_ops() as f64)),
            ("kernels", Json::Arr(kernels.iter().map(|k| Json::str(k)).collect())),
            ("metrics", m.to_json()),
        ]));
    }
    println!("  fairness p99 spread: {spread:.2}x; workspace: {wstats:?}");

    // overload economics: what was shed, rejected, or drained — and the
    // tail latency of the work that DID get served under that pressure
    let mut shed_deadline = 0u64;
    let mut failed = 0u64;
    let mut quarantine_trips = 0u64;
    let mut closed_drained = 0u64;
    for &sid in &sids {
        let m = server.metrics(sid)?;
        shed_deadline += m.shed_deadline;
        failed += m.failed;
        quarantine_trips += m.quarantine_trips;
        closed_drained += m.closed_drained;
    }
    let served_lat: Vec<f64> =
        done.iter().filter(|c| c.output().is_some()).map(|c| c.latency_ns).collect();
    // shared percentile definition (one sort, handles empty) — the same
    // one SessionMetrics' histogram is validated against
    let p99_served_ns = isplib::util::bench::percentile(&served_lat, 99.0);
    if overload {
        println!(
            "  overload: {served} served / {shed} shed / {rejected_submits} rejected at \
             admission; failed={failed} trips={quarantine_trips} drained={closed_drained}; \
             p99(served)={:.1}µs",
            p99_served_ns / 1e3
        );
    }

    // --- optional churn phase: live mutation under load -------------------
    // --churn keeps serving the flooded session while edge deltas and
    // model hot-swaps land between passes. Every completion is verified
    // bitwise against the sequential reference AT ITS ADMISSION STAMP
    // (infer_at) — the acceptance criterion for epoch-versioned serving.
    let churn = args.has("churn");
    let churn_json = if churn {
        use std::collections::HashMap;
        let delta_rate = args.get_parse("delta-rate", 8usize)?.max(1);
        let swap_every = args.get_parse("swap-every", 3usize)?.max(1);
        let target = sids[0];
        let (ds0, model0, _) = &trained[0];
        let dims0 = ModelParams { in_dim: ds0.feature_dim(), hidden, classes: ds0.num_classes };
        let (n0, f0) = (ds0.adj.rows, ds0.feature_dim());
        let mut expect: HashMap<u64, Vec<f32>> = HashMap::new();
        let mut churn_done = Vec::new();
        let mut deltas_applied = 0u64;
        let mut refreshes = 0u64;
        let mut swaps = 0u64;
        let mut churn_rejected = 0usize;
        let t_churn = Instant::now();
        for i in 0..requests {
            let x = Dense::uniform(n0, f0, 1.0, &mut rng);
            match server.submit(target, x.clone()) {
                Ok(rid) => {
                    let s = server.session(target)?;
                    let (e, v) = (s.epoch(), s.model_version());
                    expect.insert(rid, server.infer_at(target, e, v, &x)?.data);
                }
                Err(e @ Error::Overloaded { .. }) if overload => {
                    debug_assert!(e.is_retryable());
                    churn_rejected += 1;
                }
                Err(e) => return Err(e),
            }
            if (i + 1) % delta_rate == 0 {
                // a symmetric insert/upsert pair is always a valid delta
                let r = rng.gen_range(n0);
                let c = (r + 1 + rng.gen_range(n0 - 2)) % n0;
                let w = rng.gen_range_f32(0.1, 1.0);
                let delta = isplib::sparse::EdgeDelta::new().add(r, c, w).add(c, r, w);
                let out = server.apply_delta(target, &delta, Some((&tuner, &db)))?;
                deltas_applied += 1;
                refreshes += out.refreshed as u64;
                if deltas_applied % swap_every as u64 == 0 {
                    server.swap_model(target, model0.init_params(dims0, 1000 + deltas_applied))?;
                    swaps += 1;
                }
            }
            churn_done.extend(server.run_ready()?);
        }
        while server.pending() > 0 {
            churn_done.extend(server.run_ready()?);
            if server.pending() > 0 {
                std::thread::sleep(std::time::Duration::from_micros(200));
            }
        }
        let churn_wall = t_churn.elapsed().as_secs_f64();
        // bitwise acceptance at the admission stamp — served or typed-shed
        let mut churn_verified = 0usize;
        for c in &churn_done {
            match (&c.outcome, overload) {
                (Ok(out), _) => {
                    if out.data != expect[&c.id] {
                        return Err(Error::Runtime(format!(
                            "serve-bench --churn: request {} diverged from its \
                             admission-stamp (epoch, version) reference",
                            c.id
                        )));
                    }
                    churn_verified += 1;
                }
                (Err(Error::DeadlineExceeded(_)), true) => {}
                (Err(e), _) => {
                    return Err(Error::Runtime(format!(
                        "serve-bench --churn: request {} terminated {e}",
                        c.id
                    )))
                }
            }
        }
        let s = server.session(target)?;
        println!(
            "  churn: {churn_verified} requests verified bitwise at their admission stamp \
             across {deltas_applied} deltas ({refreshes} format refreshes) + {swaps} \
             hot-swaps; final epoch={} version={} live_epochs={} ({churn_wall:.3}s)",
            s.epoch(),
            s.model_version(),
            s.live_epochs()
        );
        Json::obj(vec![
            ("enabled", Json::bool(true)),
            ("requests", Json::num(requests as f64)),
            ("verified_bitwise", Json::num(churn_verified as f64)),
            ("rejected_submits", Json::num(churn_rejected as f64)),
            ("deltas", Json::num(deltas_applied as f64)),
            ("format_refreshes", Json::num(refreshes as f64)),
            ("swaps", Json::num(swaps as f64)),
            ("final_epoch", Json::num(s.epoch() as f64)),
            ("final_model_version", Json::num(s.model_version() as f64)),
            ("live_epochs", Json::num(s.live_epochs() as f64)),
            ("staleness", Json::num(cfg.staleness)),
            ("wall_secs", Json::num(churn_wall)),
        ])
    } else {
        Json::obj(vec![("enabled", Json::bool(false))])
    };

    // --- optional restart phase: warm restart from durable state ---------
    // --restart persists the session manifest and the tuning DB through
    // the durable layer, tears the whole server down (sessions, shared
    // workspace, kernel-registry contexts — a process "crash"), rebuilds
    // it from the two files, and verifies (a) restored sessions serve
    // outputs bitwise-equal to pre-restart probes, (b) tuning warm-starts
    // replay identically with zero re-measurement, and (c) serving after
    // restore never converts a format on the request path.
    let restart = args.has("restart");
    let restart_json = if restart {
        use isplib::serve::SessionManifest;
        let manifest_path = std::path::PathBuf::from(args.get("manifest", "serve_manifest.json"));
        let db_path = std::path::PathBuf::from(args.get("tuning-db", "serve_tunedb.json"));

        // pre-restart reference: one probe input/output per open session
        let mut probes = Vec::new();
        let mut warm_before = Vec::new();
        for &sid in &sids {
            let (n, f) = {
                let s = server.session(sid)?;
                warm_before.push((s.name.clone(), s.warm_started, s.preconverted, s.fused_ops()));
                (s.nodes(), s.dims.in_dim)
            };
            let x = Dense::uniform(n, f, 1.0, &mut rng);
            let y = server.infer_now(sid, &x)?;
            probes.push((x, y));
        }

        server.snapshot_manifest().save(&manifest_path)?;
        db.save(&db_path)?;
        // the "crash": close every session (unbinding global kernel
        // contexts) and drop the server with its workspace
        for &sid in &sids {
            server.close_session(sid)?;
        }

        let restored_db = TuningDb::load(&db_path)?;
        let loaded = SessionManifest::load(&manifest_path)?.ok_or_else(|| {
            Error::Runtime("serve-bench --restart: persisted manifest did not load".into())
        })?;
        server = InferenceServer::new(cfg);
        sids = server.restore_from_manifest(&loaded, Some((&tuner, &restored_db)))?;
        // format conversions after restore: exactly the registration-time
        // pre-conversions — anything above this during serving would mean
        // the hot path converted
        let misses_at_restore = server.workspace().stats().format_misses;

        let mut verified = 0usize;
        for (i, &sid) in sids.iter().enumerate() {
            let s = server.session(sid)?;
            let (name, warm0, pre0, fused0) = &warm_before[i];
            if (s.warm_started, s.preconverted, s.fused_ops()) != (*warm0, *pre0, *fused0) {
                return Err(Error::Runtime(format!(
                    "serve-bench --restart: session '{name}' warm-start diverged \
                     (warm {}→{}, formats {}→{}, fused {}→{})",
                    warm0,
                    s.warm_started,
                    pre0,
                    s.preconverted,
                    fused0,
                    s.fused_ops()
                )));
            }
            let y = server.infer_now(sid, &probes[i].0)?;
            if y.data != probes[i].1.data {
                return Err(Error::Runtime(format!(
                    "serve-bench --restart: session '{name}' output diverged after restore"
                )));
            }
            verified += 1;
        }
        let misses_after_probes = server.workspace().stats().format_misses;
        if misses_after_probes != misses_at_restore {
            return Err(Error::Runtime(format!(
                "serve-bench --restart: {} format conversions hit the request path after \
                 restore",
                misses_after_probes - misses_at_restore
            )));
        }
        println!(
            "  restart: {verified} sessions restored from {} + {} — outputs bitwise-equal, \
             warm-starts replayed ({} registration-time conversions, 0 on the request path)",
            manifest_path.display(),
            db_path.display(),
            misses_at_restore
        );
        Json::obj(vec![
            ("enabled", Json::bool(true)),
            ("manifest", Json::str(&manifest_path.display().to_string())),
            ("tuning_db", Json::str(&db_path.display().to_string())),
            ("sessions_restored", Json::num(sids.len() as f64)),
            ("verified_bitwise", Json::num(verified as f64)),
            ("format_misses_at_restore", Json::num(misses_at_restore as f64)),
            (
                "format_misses_on_request_path",
                Json::num((misses_after_probes - misses_at_restore) as f64),
            ),
        ])
    } else {
        Json::obj(vec![("enabled", Json::bool(false))])
    };

    // eviction demo: close the last session out of the shared workspace
    let last = *sids.last().unwrap();
    let evicted = server.close_session(last)?.evicted;
    println!(
        "  closed 1 session → evicted {evicted} partition entries ({} remain)",
        server.workspace().cached_partitions()
    );

    let doc = Json::obj(vec![
        (
            "config",
            Json::obj(vec![
                ("sessions", Json::num(sids.len() as f64)),
                ("requests_light", Json::num(requests as f64)),
                ("skew", Json::num(skew as f64)),
                ("max_batch", Json::num(cfg.max_batch as f64)),
                ("quantum", Json::num(cfg.quantum as f64)),
                ("max_wait_ms", Json::num(cfg.max_wait.as_secs_f64() * 1e3)),
                ("threads", Json::num(cfg.threads as f64)),
                ("session_threads", Json::num(cfg.session_threads as f64)),
                ("scale", Json::num(scale as f64)),
                ("hidden", Json::num(hidden as f64)),
                ("overload", Json::bool(overload)),
                ("queue_cap", Json::num(cfg.queue_cap as f64)),
                ("deadline_ms", Json::num(cfg.default_deadline.as_secs_f64() * 1e3)),
                ("staleness", Json::num(cfg.staleness)),
            ]),
        ),
        ("sessions", Json::Arr(sessions_json)),
        ("fairness", Json::obj(vec![("p99_spread", Json::num(spread))])),
        ("churn", churn_json),
        ("restart", restart_json),
        (
            "overload",
            Json::obj(vec![
                ("served", Json::num(served as f64)),
                ("shed_deadline", Json::num(shed_deadline as f64)),
                ("rejected_submits", Json::num(rejected_submits as f64)),
                ("failed", Json::num(failed as f64)),
                ("quarantine_trips", Json::num(quarantine_trips as f64)),
                ("closed_drained", Json::num(closed_drained as f64)),
                ("p99_served_us", Json::num(p99_served_ns / 1e3)),
            ]),
        ),
        (
            "checks",
            Json::obj(vec![
                ("completed", Json::num(done.len() as f64)),
                ("bitwise_checked", Json::num(checked as f64)),
                ("batched_bitwise_equal", Json::bool(true)),
                ("backprop_cache_untouched", Json::bool(true)),
                ("shared_pool_jobs", Json::num(pool_jobs as f64)),
                ("evicted_on_close", Json::num(evicted as f64)),
            ]),
        ),
        (
            "workspace",
            Json::obj(vec![
                ("partition_hits", Json::num(wstats.partition_hits as f64)),
                ("partition_misses", Json::num(wstats.partition_misses as f64)),
                ("buffer_reuses", Json::num(wstats.buffer_reuses as f64)),
                ("buffer_allocs", Json::num(wstats.buffer_allocs as f64)),
            ]),
        ),
        ("wall_secs", Json::num(wall)),
        // full registry snapshot: per-op labelled timing aggregates,
        // pool utilization/steal/park gauges, serve queue-depth +
        // breaker-state gauges, workspace/cache counters
        ("obs", {
            server.publish_obs();
            isplib::obs::snapshot()
        }),
    ]);
    std::fs::write(&out_path, doc.pretty())?;
    if args.has("json") {
        println!("{}", doc.pretty());
    }
    println!("serve-bench: wrote {out_path}");
    Ok(())
}

fn parse_usize_list(s: &str) -> Result<Vec<usize>> {
    s.split(',')
        .map(|t| {
            t.trim()
                .parse()
                .map_err(|_| Error::Config(format!("cannot parse '{t}' as a number")))
        })
        .collect()
}

fn parse_models(s: &str) -> Result<Vec<GnnModel>> {
    if s == "all" {
        return Ok(GnnModel::ALL.to_vec());
    }
    s.split(',').map(|m| GnnModel::parse(m.trim())).collect()
}

fn parse_datasets(s: &str) -> Result<Vec<DatasetSpec>> {
    if s == "all" {
        return Ok(paper_specs());
    }
    s.split(',')
        .map(|name| {
            spec_by_name(name.trim())
                .ok_or_else(|| Error::UnknownName(format!("dataset '{name}'")))
        })
        .collect()
}

fn parse_backends(s: &str) -> Result<Vec<Backend>> {
    if s == "all" {
        return Ok(Backend::NATIVE_ALL.to_vec());
    }
    s.split(',').map(|b| Backend::parse(b.trim())).collect()
}
