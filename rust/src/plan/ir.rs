//! The ExecutionPlan IR: a small SSA-style op graph with precomputed
//! value lifetimes and workspace slot assignments.

use crate::error::{Error, Result};
use crate::gnn::{GnnModel, ModelParams};
use crate::sparse::NormKind;

/// Index of a plan value. Value [`INPUT_VALUE`] is the feature matrix;
/// instruction `i` defines value `i + 1`.
pub type ValueId = usize;

/// The reserved value id of the input feature matrix (`n × in_dim`).
pub const INPUT_VALUE: ValueId = 0;

/// Sentinel `last_use` for the plan output: never retired.
pub(crate) const LIVE_OUT: usize = usize::MAX;

/// One plan instruction. Every op reads values (and parameters, by their
/// [`ParamSet`](crate::gnn::ParamSet) name) and defines exactly one new
/// value; row counts are always the graph's node count `n`, so only the
/// column width varies per value.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Op {
    /// `y = spmm(Â, x)` — sum-semiring aggregation over the operand's
    /// normalised adjacency, kernel routed through the registry.
    Spmm {
        /// Feature panel to aggregate.
        x: ValueId,
    },
    /// `y = x @ params[w]`.
    MatMul {
        /// Left operand.
        x: ValueId,
        /// Parameter name of the weight matrix.
        w: String,
    },
    /// `y = x + 1·params[b]ᵀ` (bias is a `1 × C` parameter row).
    BiasAdd {
        /// Input activation.
        x: ValueId,
        /// Parameter name of the bias row.
        b: String,
    },
    /// `y = max(x, 0)`.
    Relu {
        /// Input activation.
        x: ValueId,
    },
    /// `y = a + b` elementwise.
    Add {
        /// Left addend.
        a: ValueId,
        /// Right addend.
        b: ValueId,
    },
    /// `y = relu(spmm(Â, x) + params[bias]ᵀ)` in one fused kernel pass —
    /// produced only by the fusion pass
    /// ([`ExecutionPlan::fuse_spmm_relu`]), never by lowering.
    SpmmFusedRelu {
        /// Feature panel to aggregate.
        x: ValueId,
        /// Optional bias parameter folded into the epilogue.
        bias: Option<String>,
    },
}

impl Op {
    /// The value ids this op reads (operands only, not parameters).
    pub fn operands(&self) -> Vec<ValueId> {
        match self {
            Op::Spmm { x }
            | Op::MatMul { x, .. }
            | Op::BiasAdd { x, .. }
            | Op::Relu { x }
            | Op::SpmmFusedRelu { x, .. } => vec![*x],
            Op::Add { a, b } => vec![*a, *b],
        }
    }

    /// True for the aggregation ops (the ones the tuner routes).
    pub fn is_spmm(&self) -> bool {
        matches!(self, Op::Spmm { .. } | Op::SpmmFusedRelu { .. })
    }

    /// Short display name.
    pub fn name(&self) -> &'static str {
        match self {
            Op::Spmm { .. } => "spmm",
            Op::MatMul { .. } => "matmul",
            Op::BiasAdd { .. } => "bias_add",
            Op::Relu { .. } => "relu",
            Op::Add { .. } => "add",
            Op::SpmmFusedRelu { .. } => "spmm_fused_relu",
        }
    }
}

/// A lowered model: the op list plus everything both executors need
/// precomputed — per-value column widths, value lifetimes, and the
/// linear-scan workspace slot assignment. See the [module docs](super).
#[derive(Clone, Debug)]
pub struct ExecutionPlan {
    model: GnnModel,
    dims: ModelParams,
    norm: NormKind,
    ops: Vec<Op>,
    /// Column width of every value (rows are always the node count).
    cols: Vec<usize>,
    /// Per value: index of the last instruction reading it ([`LIVE_OUT`]
    /// for the plan output; the defining instruction for never-read
    /// values). Executors retire a value's buffer the moment it dies.
    last_use: Vec<usize>,
    /// Per value: the workspace size-class slot it shares with other
    /// equal-width values whose lifetimes don't overlap. `None` for the
    /// input (caller-owned) and the output (leaves with the caller).
    slot_of: Vec<Option<usize>>,
    /// Column width of each slot.
    slot_cols: Vec<usize>,
    /// Per instruction: the operand value this op may execute **in place**
    /// on (overwriting the operand's buffer instead of writing a fresh
    /// one), or `None`. See [`ExecutionPlan::inplace_operand`] for the
    /// eligibility rules.
    inplace: Vec<Option<ValueId>>,
    /// Shard count every aggregation op in this plan executes with
    /// (1 = unsharded). A *plan* property, not a per-call one: the
    /// executors stamp it onto the SpMM operand once per execution, so
    /// training, tape-free inference and serving inherit the same sharded
    /// lowering with no per-path special cases. Set by
    /// [`ExecutionPlan::with_shards`] (the serving registry applies the
    /// tuner's warm-started shard decision here); preserved by the fusion
    /// pass.
    shards: usize,
}

/// Incrementally builds a plan; used by lowering and the fusion pass.
pub(crate) struct PlanBuilder {
    model: GnnModel,
    dims: ModelParams,
    norm: NormKind,
    ops: Vec<Op>,
    cols: Vec<usize>,
}

impl PlanBuilder {
    pub(crate) fn new(model: GnnModel, dims: ModelParams, norm: NormKind) -> Self {
        PlanBuilder { model, dims, norm, ops: Vec::new(), cols: vec![dims.in_dim] }
    }

    fn value(&mut self, op: Op, out_cols: usize) -> Result<ValueId> {
        for v in op.operands() {
            if v >= self.cols.len() {
                return Err(Error::Config(format!(
                    "plan: op {} reads undefined value {v}",
                    op.name()
                )));
            }
        }
        self.ops.push(op);
        self.cols.push(out_cols);
        Ok(self.cols.len() - 1)
    }

    pub(crate) fn spmm(&mut self, x: ValueId) -> Result<ValueId> {
        let c = self.cols[x];
        self.value(Op::Spmm { x }, c)
    }

    /// `out_cols` is the weight's column count — the lowering knows the
    /// architecture, so no parameter matrices are materialised here.
    pub(crate) fn matmul(&mut self, x: ValueId, w: &str, out_cols: usize) -> Result<ValueId> {
        self.value(Op::MatMul { x, w: w.to_string() }, out_cols)
    }

    pub(crate) fn bias_add(&mut self, x: ValueId, b: &str) -> Result<ValueId> {
        let c = self.cols[x];
        self.value(Op::BiasAdd { x, b: b.to_string() }, c)
    }

    pub(crate) fn relu(&mut self, x: ValueId) -> Result<ValueId> {
        let c = self.cols[x];
        self.value(Op::Relu { x }, c)
    }

    pub(crate) fn add(&mut self, a: ValueId, b: ValueId) -> Result<ValueId> {
        if self.cols[a] != self.cols[b] {
            return Err(Error::ShapeMismatch(format!(
                "plan add: value {a} has {} cols, value {b} has {}",
                self.cols[a], self.cols[b]
            )));
        }
        let c = self.cols[a];
        self.value(Op::Add { a, b }, c)
    }

    pub(crate) fn spmm_fused_relu(&mut self, x: ValueId, bias: Option<String>) -> Result<ValueId> {
        let c = self.cols[x];
        self.value(Op::SpmmFusedRelu { x, bias }, c)
    }

    /// Seal the plan: compute lifetimes and the slot assignment.
    pub(crate) fn finish(self) -> ExecutionPlan {
        let PlanBuilder { model, dims, norm, ops, cols } = self;
        let nvals = cols.len();
        let output = nvals - 1;

        // last use: defining point by default, overwritten by later reads
        let mut last_use: Vec<usize> = (0..nvals).map(|v| v.saturating_sub(1)).collect();
        for (i, op) in ops.iter().enumerate() {
            for v in op.operands() {
                last_use[v] = i;
            }
        }
        last_use[output] = LIVE_OUT;

        // linear-scan slot assignment: a dying value's slot is reusable by
        // the next same-width value born after it. Operands are normally
        // released AFTER the instruction's own output is placed, so an
        // op's output never aliases one of its inputs — EXCEPT for the
        // in-place elementwise ops below, where the aliasing is the point:
        // when a Relu/BiasAdd/Add operand dies at its consuming
        // instruction, the output takes over the operand's slot (and, in
        // the inference executor, its buffer), eliding one full matrix
        // write+read per op. Kernel-backed ops (Spmm / MatMul / the fused
        // op) never qualify — kernels need a zeroed output and read their
        // input throughout the call.
        let mut slot_of: Vec<Option<usize>> = vec![None; nvals];
        let mut slot_cols: Vec<usize> = Vec::new();
        let mut inplace: Vec<Option<ValueId>> = vec![None; ops.len()];
        let mut free: std::collections::HashMap<usize, Vec<usize>> =
            std::collections::HashMap::new();
        for (i, op) in ops.iter().enumerate() {
            let out = i + 1;
            // in-place candidate: an elementwise op whose operand dies
            // here. The plan output never executes in place (it must land
            // in a caller-owned buffer); the input is caller-owned too.
            // For Add either operand qualifies (the executor has both
            // accumulator orders); the left one is preferred.
            let chosen = if out == output {
                None
            } else {
                let mut cands: Vec<ValueId> = match op {
                    Op::Relu { x } | Op::BiasAdd { x, .. } => vec![*x],
                    Op::Add { a, b } if a != b => vec![*a, *b],
                    _ => Vec::new(),
                };
                cands.retain(|&v| v != INPUT_VALUE && last_use[v] == i);
                cands.first().copied()
            };
            if out != output {
                match chosen {
                    // the output inherits the dying operand's slot — all
                    // in-place ops preserve width, so the class matches
                    Some(v) => {
                        slot_of[out] = slot_of[v];
                        inplace[i] = Some(v);
                    }
                    None => {
                        let c = cols[out];
                        let slot = match free.get_mut(&c).and_then(|f| f.pop()) {
                            Some(s) => s,
                            None => {
                                slot_cols.push(c);
                                slot_cols.len() - 1
                            }
                        };
                        slot_of[out] = Some(slot);
                    }
                }
            }
            let mut seen = Vec::new();
            for v in op.operands() {
                if v == INPUT_VALUE || last_use[v] != i || seen.contains(&v) {
                    continue;
                }
                seen.push(v);
                // the in-place operand's slot transferred to the output —
                // it is NOT free
                if chosen == Some(v) {
                    continue;
                }
                if let Some(s) = slot_of[v] {
                    free.entry(cols[v]).or_default().push(s);
                }
            }
        }

        ExecutionPlan {
            model,
            dims,
            norm,
            ops,
            cols,
            last_use,
            slot_of,
            slot_cols,
            inplace,
            shards: 1,
        }
    }
}

impl ExecutionPlan {
    /// The model this plan was lowered from.
    pub fn model(&self) -> GnnModel {
        self.model
    }

    /// The dimensions the plan was lowered for.
    pub fn dims(&self) -> ModelParams {
        self.dims
    }

    /// The adjacency normalisation the plan's SpMM ops expect the operand
    /// to carry (recorded at lowering; the executors consume an already
    /// normalised operand).
    pub fn norm(&self) -> NormKind {
        self.norm
    }

    /// The instruction list, in execution order.
    pub fn ops(&self) -> &[Op] {
        &self.ops
    }

    /// Total number of values (input + one per instruction).
    pub fn num_values(&self) -> usize {
        self.cols.len()
    }

    /// The value id holding the logits.
    pub fn output(&self) -> ValueId {
        self.cols.len() - 1
    }

    /// Column width of a value.
    pub fn value_cols(&self, v: ValueId) -> usize {
        self.cols[v]
    }

    /// The input feature width the plan expects.
    pub fn in_dim(&self) -> usize {
        self.cols[INPUT_VALUE]
    }

    /// Index of the last instruction reading `v` (its defining instruction
    /// if never read; `usize::MAX` for the output).
    pub fn last_use(&self, v: ValueId) -> usize {
        self.last_use[v]
    }

    /// The workspace slot assigned to `v` (`None` for the input and the
    /// output, which are caller-owned).
    pub fn slot_of(&self, v: ValueId) -> Option<usize> {
        self.slot_of[v]
    }

    /// Number of workspace size-class slots the plan needs concurrently —
    /// the steady-state pooled-buffer bound per request.
    pub fn num_slots(&self) -> usize {
        self.slot_cols.len()
    }

    /// Shard count the plan's aggregation ops execute with (1 = unsharded).
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Return this plan with its aggregation ops lowered to `shards`-way
    /// sharded execution (`0` normalises to 1). Sharding is bitwise-equal
    /// to the flat plan for values and gradients — see
    /// [`crate::kernels::spmm_sharded`] — so the choice is purely a
    /// performance decision, owned by the tuner's shard-count axis and
    /// warm-started through the `TuningDb` like kernel, format and fusion.
    pub fn with_shards(mut self, shards: usize) -> Self {
        self.shards = shards.max(1);
        self
    }

    /// The operand instruction `i` may execute **in place** on, or `None`.
    ///
    /// Eligibility (computed once at plan sealing): the op is an
    /// elementwise dense op (`Relu`, `BiasAdd`, `Add`), the operand is not
    /// the plan input, it **dies at this instruction** (`last_use == i`,
    /// so no later reader exists), it is not the same value as the op's
    /// other operand, and the op does not define the plan output (which
    /// must land in a caller-owned buffer). The output then shares the
    /// operand's slot; the inference executor overwrites the operand's
    /// buffer with the new in-place [`Dense`](crate::dense::Dense)
    /// kernels instead of a `_into` copy. For `Add`, the returned id says
    /// which side is the accumulator (left preferred; either works,
    /// `a + b` evaluated in that order both ways).
    pub fn inplace_operand(&self, i: usize) -> Option<ValueId> {
        self.inplace[i]
    }

    /// Column width of each slot.
    pub fn slot_widths(&self) -> &[usize] {
        &self.slot_cols
    }

    /// The embedding widths the plan's aggregation ops run SpMM at —
    /// sorted and deduplicated. By symmetry of `dX = spmm(Aᵀ, dY)`, the
    /// backward pass hits exactly the same widths, so this is the complete
    /// set a tuner must cover before kernel routing pays off. Replaces the
    /// hand-maintained per-model width lists.
    pub fn spmm_shapes(&self) -> Vec<usize> {
        let mut ks: Vec<usize> = self
            .ops
            .iter()
            .filter(|op| op.is_spmm())
            .flat_map(|op| op.operands())
            .map(|v| self.cols[v])
            .collect();
        ks.sort_unstable();
        ks.dedup();
        ks
    }

    /// [`ExecutionPlan::spmm_shapes`] extended with every coalesced
    /// multiple up to `max_batch` — the widths batched inference
    /// ([`crate::serve`]) actually runs SpMM at when `b` same-graph
    /// requests share one call. Sorted and deduplicated.
    pub fn spmm_shapes_batched(&self, max_batch: usize) -> Vec<usize> {
        let mut ks = Vec::new();
        for base in self.spmm_shapes() {
            for b in 1..=max_batch.max(1) {
                ks.push(base * b);
            }
        }
        ks.sort_unstable();
        ks.dedup();
        ks
    }

    /// Estimated floating-point operations for one execution of this plan
    /// over a graph with `rows` nodes and `nnz` stored non-zeros — the
    /// cost model behind the serving layer's FLOPs-based admission
    /// control. Per-op costs follow the standard dense/sparse counts (an
    /// SpMM at width `k` is `2·nnz·k`, a GEMM is `2·rows·k_in·k_out`,
    /// elementwise ops are `rows·k`); the op-level GNN benchmarking
    /// literature shows these shape/nnz products track measured per-op
    /// time well, which is all an admission gate needs — relative cost,
    /// not cycle accuracy.
    pub fn estimated_flops(&self, rows: usize, nnz: usize) -> f64 {
        let mut total = 0.0f64;
        for (i, op) in self.ops.iter().enumerate() {
            let out = i + 1;
            total += match op {
                Op::Spmm { x } => 2.0 * nnz as f64 * self.cols[*x] as f64,
                Op::SpmmFusedRelu { x, .. } => {
                    // the aggregation plus the fused bias+relu epilogue
                    2.0 * nnz as f64 * self.cols[*x] as f64
                        + 2.0 * rows as f64 * self.cols[out] as f64
                }
                Op::MatMul { x, .. } => {
                    2.0 * rows as f64 * self.cols[*x] as f64 * self.cols[out] as f64
                }
                Op::BiasAdd { .. } | Op::Relu { .. } | Op::Add { .. } => {
                    rows as f64 * self.cols[out] as f64
                }
            };
        }
        total
    }

    /// Number of [`Op::SpmmFusedRelu`] instructions in the plan.
    pub fn fused_op_count(&self) -> usize {
        self.ops.iter().filter(|op| matches!(op, Op::SpmmFusedRelu { .. })).count()
    }

    /// The SpMM widths at which this plan has a fusable `Spmm→Relu` /
    /// `Spmm→BiasAdd→Relu` chain — the widths the tuner should measure the
    /// fused epilogue at. Computed by running the fusion matcher with an
    /// always-profitable predicate.
    pub fn fusable_spmm_widths(&self) -> Vec<usize> {
        let fused = self.fuse_spmm_relu(|_| true);
        let mut ks: Vec<usize> = fused
            .ops
            .iter()
            .filter_map(|op| match op {
                Op::SpmmFusedRelu { x, .. } => Some(fused.cols[*x]),
                _ => None,
            })
            .collect();
        ks.sort_unstable();
        ks.dedup();
        ks
    }

    /// Rebuild helper for plan-rewrite passes.
    pub(crate) fn rebuilder(&self) -> PlanBuilder {
        PlanBuilder::new(self.model, self.dims, self.norm)
    }

    /// Internal accessor for rewrite passes.
    pub(crate) fn cols_slice(&self) -> &[usize] {
        &self.cols
    }

    /// One-line-per-op description (debugging, bench logs).
    pub fn describe(&self) -> String {
        use std::fmt::Write;
        let mut s = String::new();
        let _ = writeln!(
            s,
            "plan {} (in={} hidden={} classes={}, {} ops, {} slots)",
            self.model.name(),
            self.dims.in_dim,
            self.dims.hidden,
            self.dims.classes,
            self.ops.len(),
            self.num_slots()
        );
        for (i, op) in self.ops.iter().enumerate() {
            let _ = writeln!(s, "  v{} = {:?}  [cols={}]", i + 1, op, self.cols[i + 1]);
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn estimated_flops_matches_hand_count_for_gcn() {
        let dims = ModelParams { in_dim: 4, hidden: 8, classes: 3 };
        let plan = GnnModel::Gcn.lower(dims, GnnModel::Gcn.norm_kind());
        let (n, m) = (100usize, 500usize);
        // GCN lowers to matmul→spmm→bias→relu→matmul→spmm→bias
        let (nf, h, c, nnz, rows) = (4.0, 8.0, 3.0, m as f64, n as f64);
        let want = 2.0 * rows * nf * h      // matmul 1
            + 2.0 * nnz * h                 // spmm(hidden)
            + rows * h                      // bias
            + rows * h                      // relu
            + 2.0 * rows * h * c            // matmul 2
            + 2.0 * nnz * c                 // spmm(classes)
            + rows * c; // bias
        assert!((plan.estimated_flops(n, m) - want).abs() < 1e-6);
        // more edges or more nodes always cost more
        assert!(plan.estimated_flops(n, 2 * m) > plan.estimated_flops(n, m));
        assert!(plan.estimated_flops(2 * n, m) > plan.estimated_flops(n, m));
    }

    #[test]
    fn fusing_spmm_bias_relu_preserves_estimated_flops() {
        // the fused op does the same arithmetic as its spmm→bias→relu
        // chain, so the cost model must agree across the fusion pass
        let dims = ModelParams { in_dim: 4, hidden: 8, classes: 3 };
        let plan = GnnModel::Gcn.lower(dims, GnnModel::Gcn.norm_kind());
        let fused = plan.fuse_spmm_relu(|_| true);
        assert!(fused.fused_op_count() > 0);
        let (a, b) = (plan.estimated_flops(64, 256), fused.estimated_flops(64, 256));
        assert!((a - b).abs() < 1e-6, "unfused {a} vs fused {b}");
    }
}
