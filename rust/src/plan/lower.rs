//! Lowering: [`GnnModel`] → [`ExecutionPlan`].
//!
//! Each model lowers to the exact dataflow its hand-written tape forward
//! used to record, op for op, so the plan-driven executors reproduce the
//! pre-plan numerics bitwise. Lowering never emits the fused op — fusion
//! is a separate, tuning-gated rewrite ([`ExecutionPlan::fuse_spmm_relu`]).

use crate::gnn::{GnnModel, ModelParams};
use crate::sparse::NormKind;

use super::ir::{ExecutionPlan, PlanBuilder, INPUT_VALUE};

impl GnnModel {
    /// Lower this model to an [`ExecutionPlan`] for the given dimensions.
    ///
    /// `norm` is the adjacency normalisation the plan's SpMM operand must
    /// carry — pass [`GnnModel::norm_kind`] unless deliberately training
    /// against a different normalisation. The plan records it so executors
    /// and sessions can audit the pairing; it does not normalise anything
    /// itself.
    pub fn lower(self, dims: ModelParams, norm: NormKind) -> ExecutionPlan {
        let mut p = PlanBuilder::new(self, dims, norm);
        // the builder only errors on malformed value references, which a
        // lowering bug would hit on the very first unit test — expect here
        // keeps every caller infallible
        self.lower_ops(&mut p, dims).expect("model lowering is structurally valid");
        p.finish()
    }

    fn lower_ops(self, p: &mut PlanBuilder, dims: ModelParams) -> crate::error::Result<()> {
        let x = INPUT_VALUE;
        let ModelParams { hidden, classes, .. } = dims;
        match self {
            GnnModel::Gcn => {
                // layer 0: project *then* aggregate (K = hidden in the SpMM)
                let xw = p.matmul(x, "w0", hidden)?;
                let agg = p.spmm(xw)?;
                let h = p.bias_add(agg, "b0")?;
                let h = p.relu(h)?;
                // layer 1
                let hw = p.matmul(h, "w1", classes)?;
                let agg = p.spmm(hw)?;
                p.bias_add(agg, "b1")?;
            }
            GnnModel::SageSum | GnnModel::SageMean => {
                // layer 0: aggregate raw features *then* project (K = in_dim)
                let neigh = p.spmm(x)?;
                let neigh = p.matmul(neigh, "w0_neigh", hidden)?;
                let selfp = p.matmul(x, "w0_self", hidden)?;
                let h = p.add(selfp, neigh)?;
                let h = p.bias_add(h, "b0")?;
                let h = p.relu(h)?;
                // layer 1
                let neigh = p.spmm(h)?;
                let neigh = p.matmul(neigh, "w1_neigh", classes)?;
                let selfp = p.matmul(h, "w1_self", classes)?;
                let out = p.add(selfp, neigh)?;
                p.bias_add(out, "b1")?;
            }
            GnnModel::Gin => {
                // layer 0: z = (1+ε)x + Σ_neigh x, ε = 0, then the 2-layer MLP
                let agg = p.spmm(x)?;
                let z = p.add(x, agg)?;
                let h = p.matmul(z, "w0a", hidden)?;
                let h = p.bias_add(h, "b0a")?;
                let h = p.relu(h)?;
                let h = p.matmul(h, "w0b", hidden)?;
                let h = p.bias_add(h, "b0b")?;
                let h = p.relu(h)?;
                // layer 1
                let agg = p.spmm(h)?;
                let z = p.add(h, agg)?;
                let out = p.matmul(z, "w1", classes)?;
                p.bias_add(out, "b1")?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::super::Op;
    use crate::gnn::{GnnModel, ModelParams};

    fn dims() -> ModelParams {
        ModelParams { in_dim: 50, hidden: 16, classes: 3 }
    }

    #[test]
    fn spmm_shapes_match_forward_structure() {
        // the widths the deleted GnnModel::spmm_widths used to report
        assert_eq!(GnnModel::Gcn.lower(dims(), GnnModel::Gcn.norm_kind()).spmm_shapes(), vec![
            3, 16
        ]);
        for m in [GnnModel::SageSum, GnnModel::SageMean, GnnModel::Gin] {
            assert_eq!(m.lower(dims(), m.norm_kind()).spmm_shapes(), vec![16, 50], "{m:?}");
        }
        // duplicates collapse (hidden == in_dim)
        let square = ModelParams { in_dim: 16, hidden: 16, classes: 2 };
        assert_eq!(
            GnnModel::Gin.lower(square, GnnModel::Gin.norm_kind()).spmm_shapes(),
            vec![16]
        );
    }

    #[test]
    fn batched_shapes_cover_coalesced_multiples() {
        // the widths the deleted serving_spmm_widths used to report
        let plan = GnnModel::Gcn.lower(dims(), GnnModel::Gcn.norm_kind());
        assert_eq!(plan.spmm_shapes_batched(2), vec![3, 6, 16, 32]);
        assert_eq!(plan.spmm_shapes_batched(1), vec![3, 16]);
        assert_eq!(plan.spmm_shapes_batched(0), vec![3, 16]);
    }

    #[test]
    fn lowered_plans_have_expected_structure() {
        let gcn = GnnModel::Gcn.lower(dims(), GnnModel::Gcn.norm_kind());
        assert_eq!(gcn.ops().len(), 7);
        assert_eq!(gcn.output(), 7);
        assert_eq!(gcn.in_dim(), 50);
        assert_eq!(gcn.value_cols(gcn.output()), 3);
        assert!(matches!(gcn.ops()[0], Op::MatMul { .. }));
        assert!(matches!(gcn.ops().last().unwrap(), Op::BiasAdd { .. }));
        assert_eq!(gcn.fused_op_count(), 0, "lowering never fuses");

        let sage = GnnModel::SageSum.lower(dims(), GnnModel::SageSum.norm_kind());
        assert_eq!(sage.ops().iter().filter(|o| o.is_spmm()).count(), 2);
        assert_eq!(sage.value_cols(sage.output()), 3);

        let gin = GnnModel::Gin.lower(dims(), GnnModel::Gin.norm_kind());
        assert_eq!(gin.ops().iter().filter(|o| matches!(o, Op::Relu { .. })).count(), 2);
        assert_eq!(gin.value_cols(gin.output()), 3);
        assert!(!gin.describe().is_empty());
    }

    /// In-place slot execution wiring: elementwise ops whose operand dies
    /// at the defining instruction share the operand's slot; kernel ops,
    /// the plan output, and the plan input never participate.
    #[test]
    fn inplace_assignment_follows_the_rules() {
        for model in GnnModel::ALL {
            let plan = model.lower(dims(), model.norm_kind());
            for (i, op) in plan.ops().iter().enumerate() {
                let out = i + 1;
                match plan.inplace_operand(i) {
                    Some(v) => {
                        // only elementwise ops; operand from this op; dies here
                        assert!(
                            matches!(op, Op::Relu { .. } | Op::BiasAdd { .. } | Op::Add { .. }),
                            "{model:?}: {op:?} cannot run in place"
                        );
                        assert!(op.operands().contains(&v), "{model:?} i={i}");
                        assert_ne!(v, 0, "{model:?}: plan input must not be overwritten");
                        assert_eq!(plan.last_use(v), i, "{model:?}: operand outlives op");
                        assert_ne!(out, plan.output(), "{model:?}: output is caller-owned");
                        // the output inherits the operand's slot
                        assert_eq!(plan.slot_of(out), plan.slot_of(v), "{model:?} i={i}");
                    }
                    None => {
                        if let (Op::Relu { x } | Op::BiasAdd { x, .. }) = op {
                            // a skipped unary elementwise op means the
                            // operand is shared, is the input, or the op
                            // defines the output
                            assert!(
                                *x == 0 || plan.last_use(*x) > i || out == plan.output(),
                                "{model:?} i={i}: missed in-place opportunity"
                            );
                        }
                    }
                }
            }
        }
        // GCN layer 0: spmm's value dies at bias_add, bias_add's at relu —
        // both run in place (the concrete case from the motivation)
        let gcn = GnnModel::Gcn.lower(dims(), GnnModel::Gcn.norm_kind());
        let inplace: Vec<bool> =
            (0..gcn.ops().len()).map(|i| gcn.inplace_operand(i).is_some()).collect();
        assert_eq!(inplace, vec![false, false, true, true, false, false, false]);
        // GIN layer 0's z = add(x, agg): only the RIGHT operand (agg) is a
        // non-input dying value — the radd accumulator case
        let gin = GnnModel::Gin.lower(dims(), GnnModel::Gin.norm_kind());
        let Op::Add { a, b } = &gin.ops()[1] else { panic!("GIN op 1 is the residual add") };
        assert_eq!(*a, 0, "left operand is the plan input");
        assert_eq!(gin.inplace_operand(1), Some(*b));
    }

    #[test]
    fn lifetimes_and_slots_are_consistent() {
        for model in GnnModel::ALL {
            let plan = model.lower(dims(), model.norm_kind());
            // the output is permanently live and unslotted; the input is
            // caller-owned
            assert_eq!(plan.last_use(plan.output()), usize::MAX, "{model:?}");
            assert!(plan.slot_of(plan.output()).is_none(), "{model:?}");
            assert!(plan.slot_of(0).is_none(), "{model:?}");
            // every intermediate has a slot whose width matches the value
            for v in 1..plan.output() {
                let slot = plan.slot_of(v).expect("intermediate values are slotted");
                assert_eq!(plan.slot_widths()[slot], plan.value_cols(v), "{model:?} v{v}");
                // a value is read at or after its definition
                assert!(plan.last_use(v) >= v - 1, "{model:?} v{v}");
            }
            // slot sharing is real: fewer slots than intermediates
            assert!(plan.num_slots() < plan.output() - 1, "{model:?}: {}", plan.describe());
            // two live-at-once values never share a slot
            for v in 1..plan.num_values() {
                for w in (v + 1)..plan.num_values() {
                    if let (Some(sv), Some(sw)) = (plan.slot_of(v), plan.slot_of(w)) {
                        if sv == sw {
                            // w is born at instr w-1; v must be dead by then
                            assert!(
                                plan.last_use(v) < w,
                                "{model:?}: v{v} and v{w} share slot {sv} while overlapping"
                            );
                        }
                    }
                }
            }
        }
    }
}
