//! The `Spmm→Relu` fusion pass.
//!
//! Rewrites single-consumer chains
//!
//! * `v = Spmm(x); y = Relu(v)`            → `y = SpmmFusedRelu(x)`
//! * `v = Spmm(x); w = BiasAdd(v, b); y = Relu(w)`
//!                                         → `y = SpmmFusedRelu(x, bias=b)`
//!
//! into the FusedMM-backed fused op, eliminating one (or two) full passes
//! over the `n × K` activation per rewritten layer. The rewrite is sound
//! only when the intermediate values have **no other consumer** — the pass
//! checks use counts (the plan output counts as a use) and leaves shared
//! values alone.
//!
//! Bitwise invariant: the fused kernel performs exactly the unfused
//! chain's per-element operations in the same order (see
//! [`spmm_fused_relu`](crate::kernels::spmm_fused_relu)), so a fused plan
//! is bitwise-equal to its unfused source for every kernel family and
//! sparse format — equality by construction, property-tested in
//! `tests/plan_integration.rs`.
//!
//! Whether to rewrite an edge is a *tuning* decision: callers pass a
//! per-SpMM-width `profitable` predicate, normally backed by the
//! [`TuningDb`](crate::autotune::TuningDb)'s measured `fuse_relu` entries
//! ([`TuningDb::fused_relu_profitable`](crate::autotune::TuningDb::fused_relu_profitable)),
//! so fusion only happens where the fused kernel actually measured faster
//! on this graph and machine.

use super::ir::{ExecutionPlan, Op, ValueId, INPUT_VALUE};

#[derive(Clone)]
enum Action {
    Keep,
    Drop,
    Fused { x: ValueId, bias: Option<String> },
}

impl ExecutionPlan {
    /// Rewrite fusable `Spmm→[BiasAdd→]Relu` chains whose SpMM width `k`
    /// satisfies `profitable(k)` into [`Op::SpmmFusedRelu`]; returns the
    /// rewritten plan (lifetimes and slots recomputed). A plan with no
    /// fusable or profitable edges is returned structurally unchanged.
    pub fn fuse_spmm_relu(&self, profitable: impl Fn(usize) -> bool) -> ExecutionPlan {
        let ops = self.ops();
        let cols = self.cols_slice();
        let nvals = self.num_values();

        let mut uses = vec![0usize; nvals];
        for op in ops {
            for v in op.operands() {
                uses[v] += 1;
            }
        }
        // the logits leave the plan: that is a use
        uses[self.output()] += 1;
        // for single-use values, the index of their one consuming instr
        let mut consumer = vec![usize::MAX; nvals];
        for (i, op) in ops.iter().enumerate() {
            for v in op.operands() {
                consumer[v] = i;
            }
        }

        let mut actions: Vec<Action> = vec![Action::Keep; ops.len()];
        for (i, op) in ops.iter().enumerate() {
            let Op::Spmm { x } = op else { continue };
            let vi = i + 1;
            if uses[vi] != 1 || !profitable(cols[*x]) {
                continue;
            }
            let j = consumer[vi];
            if j == usize::MAX {
                continue; // the spmm value IS the output
            }
            match &ops[j] {
                Op::Relu { .. } => {
                    actions[i] = Action::Drop;
                    actions[j] = Action::Fused { x: *x, bias: None };
                }
                Op::BiasAdd { b, .. } => {
                    let vj = j + 1;
                    if uses[vj] != 1 {
                        continue;
                    }
                    let l = consumer[vj];
                    if l != usize::MAX && matches!(ops[l], Op::Relu { .. }) {
                        actions[i] = Action::Drop;
                        actions[j] = Action::Drop;
                        actions[l] = Action::Fused { x: *x, bias: Some(b.clone()) };
                    }
                }
                _ => {}
            }
        }

        // rebuild, remapping value ids across the dropped instructions
        let mut builder = self.rebuilder();
        let mut remap: Vec<ValueId> = vec![usize::MAX; nvals];
        remap[INPUT_VALUE] = INPUT_VALUE;
        for (i, op) in ops.iter().enumerate() {
            let old_out = i + 1;
            let new = match (&actions[i], op) {
                (Action::Drop, _) => continue,
                (Action::Fused { x, bias }, _) => builder.spmm_fused_relu(remap[*x], bias.clone()),
                (Action::Keep, Op::Spmm { x }) => builder.spmm(remap[*x]),
                (Action::Keep, Op::MatMul { x, w }) => builder.matmul(remap[*x], w, cols[old_out]),
                (Action::Keep, Op::BiasAdd { x, b }) => builder.bias_add(remap[*x], b),
                (Action::Keep, Op::Relu { x }) => builder.relu(remap[*x]),
                (Action::Keep, Op::Add { a, b }) => builder.add(remap[*a], remap[*b]),
                (Action::Keep, Op::SpmmFusedRelu { x, bias }) => {
                    builder.spmm_fused_relu(remap[*x], bias.clone())
                }
            }
            .expect("fusion rewrite preserves plan validity");
            remap[old_out] = new;
        }
        // fusion is a structural rewrite; the plan's shard lowering is an
        // orthogonal property and must survive it
        builder.finish().with_shards(self.shards())
    }
}

#[cfg(test)]
mod tests {
    use super::super::ir::{PlanBuilder, INPUT_VALUE};
    use super::*;
    use crate::gnn::{GnnModel, ModelParams};
    use crate::sparse::NormKind;

    fn dims() -> ModelParams {
        ModelParams { in_dim: 50, hidden: 16, classes: 3 }
    }

    #[test]
    fn gcn_layer0_chain_fuses_and_layer1_does_not() {
        let plan = GnnModel::Gcn.lower(dims(), NormKind::GcnSym);
        let fused = plan.fuse_spmm_relu(|_| true);
        // layer 0's spmm → bias_add → relu collapses into one op; layer
        // 1's spmm → bias_add (no relu) stays
        assert_eq!(fused.fused_op_count(), 1);
        assert_eq!(fused.ops().len(), plan.ops().len() - 2);
        let f = fused
            .ops()
            .iter()
            .find_map(|op| match op {
                Op::SpmmFusedRelu { bias, .. } => Some(bias.clone()),
                _ => None,
            })
            .unwrap();
        assert_eq!(f.as_deref(), Some("b0"), "the layer-0 bias folds into the epilogue");
        // the tuner's width view is unchanged by fusion
        assert_eq!(fused.spmm_shapes(), plan.spmm_shapes());
        assert_eq!(plan.fusable_spmm_widths(), vec![16], "GCN fuses at the hidden width");
    }

    #[test]
    fn sage_and_gin_have_no_fusable_chain() {
        // SAGE's relu consumes an Add-fed BiasAdd; GIN's relus consume
        // MatMul-fed BiasAdds — no Spmm feeds a relu chain directly
        for model in [GnnModel::SageSum, GnnModel::SageMean, GnnModel::Gin] {
            let plan = model.lower(dims(), model.norm_kind());
            let fused = plan.fuse_spmm_relu(|_| true);
            assert_eq!(fused.fused_op_count(), 0, "{model:?}");
            assert_eq!(fused.ops().len(), plan.ops().len(), "{model:?}");
            assert!(plan.fusable_spmm_widths().is_empty(), "{model:?}");
        }
    }

    #[test]
    fn profitability_predicate_gates_the_rewrite() {
        let plan = GnnModel::Gcn.lower(dims(), NormKind::GcnSym);
        // GCN's fusable edge runs at K = hidden = 16; refuse that width
        let fused = plan.fuse_spmm_relu(|k| k != 16);
        assert_eq!(fused.fused_op_count(), 0);
        assert_eq!(fused.ops().len(), plan.ops().len());
        let fused = plan.fuse_spmm_relu(|k| k == 16);
        assert_eq!(fused.fused_op_count(), 1);
    }

    #[test]
    fn bare_spmm_relu_edge_fuses_without_bias() {
        let mut b = PlanBuilder::new(GnnModel::Gcn, dims(), NormKind::None);
        let agg = b.spmm(INPUT_VALUE).unwrap();
        let r = b.relu(agg).unwrap();
        b.matmul(r, "w0", 16).unwrap();
        let plan = b.finish();
        let fused = plan.fuse_spmm_relu(|_| true);
        assert_eq!(fused.fused_op_count(), 1);
        assert!(matches!(fused.ops()[0], Op::SpmmFusedRelu { bias: None, .. }));
        assert_eq!(fused.ops().len(), 2);
    }

    #[test]
    fn shared_intermediates_are_not_fused() {
        // the spmm value feeds BOTH a relu and an add — fusing would
        // delete a value another op still needs
        let mut b = PlanBuilder::new(GnnModel::Gcn, dims(), NormKind::None);
        let agg = b.spmm(INPUT_VALUE).unwrap();
        let r = b.relu(agg).unwrap();
        b.add(r, agg).unwrap();
        let plan = b.finish();
        let fused = plan.fuse_spmm_relu(|_| true);
        assert_eq!(fused.fused_op_count(), 0);
        assert_eq!(fused.ops().len(), plan.ops().len());

        // likewise when the bias_add intermediate is shared
        let mut b = PlanBuilder::new(GnnModel::Gcn, dims(), NormKind::None);
        let agg = b.spmm(INPUT_VALUE).unwrap();
        let h = b.bias_add(agg, "b0").unwrap();
        let r = b.relu(h).unwrap();
        b.add(r, h).unwrap();
        let plan = b.finish();
        assert_eq!(plan.fuse_spmm_relu(|_| true).fused_op_count(), 0);
    }

    #[test]
    fn output_spmm_is_never_fused() {
        // a plan ending in a bare spmm: its value is the output, not a
        // fusable edge
        let mut b = PlanBuilder::new(GnnModel::Gcn, dims(), NormKind::None);
        b.spmm(INPUT_VALUE).unwrap();
        let plan = b.finish();
        let fused = plan.fuse_spmm_relu(|_| true);
        assert_eq!(fused.fused_op_count(), 0);
        assert_eq!(fused.ops().len(), 1);
    }

    #[test]
    fn fusion_preserves_shard_lowering() {
        let plan = GnnModel::Gcn.lower(dims(), NormKind::GcnSym).with_shards(4);
        let fused = plan.fuse_spmm_relu(|_| true);
        assert_eq!(fused.fused_op_count(), 1);
        assert_eq!(fused.shards(), 4, "the shard count survives the rewrite");
        // and the no-op rewrite keeps it too
        let unfused = plan.fuse_spmm_relu(|_| false);
        assert_eq!(unfused.shards(), 4);
    }

    #[test]
    fn fusing_twice_is_idempotent() {
        let plan = GnnModel::Gcn.lower(dims(), NormKind::GcnSym);
        let once = plan.fuse_spmm_relu(|_| true);
        let twice = once.fuse_spmm_relu(|_| true);
        assert_eq!(once.ops(), twice.ops());
        assert_eq!(once.num_slots(), twice.num_slots());
    }
}
