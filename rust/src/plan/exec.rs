//! The two plan executors: tape-recording training and tape-free batched
//! inference — one op list, two interpreters.

use std::collections::BTreeMap;

use crate::autodiff::{SpmmImpl, SpmmOperand, Tape, Var};
use crate::autotune::KernelRegistry;
use crate::dense::{concat_cols_into, split_cols_into, Dense};
use crate::error::{Error, Result};
use crate::gnn::ParamSet;
use crate::kernels::{
    fused_relu_epilogue, spmm_fused_relu_sharded, spmm_sharded, KernelWorkspace, Semiring,
};
use crate::obs;
use crate::util::json::Json;
use crate::util::parallel;

use super::ir::{ExecutionPlan, Op, ValueId, INPUT_VALUE};

/// Per-instruction observability span for both executors: named by the op
/// mnemonic, carrying `(kernel, format, rows, nnz, k, threads, fused,
/// inplace)` args for the trace viewer, and aggregated per-op under a
/// bounded `op.<name>{...}` label (kernel/format labels come from a fixed
/// candidate family, `k` from the model dims, `threads` from the budget —
/// see the cardinality rules in [`crate::obs`]). Inert — one relaxed load,
/// no allocation — while observability is off.
fn instr_span(
    plan: &ExecutionPlan,
    i: usize,
    op: &Op,
    operand: &SpmmOperand,
    threads: usize,
) -> obs::Span {
    if !obs::active() {
        return obs::Span::enter("op");
    }
    let name = match op {
        Op::Spmm { .. } => "spmm",
        Op::MatMul { .. } => "matmul",
        Op::BiasAdd { .. } => "bias_add",
        Op::Relu { .. } => "relu",
        Op::Add { .. } => "add",
        Op::SpmmFusedRelu { .. } => "spmm_fused_relu",
    };
    let k = op.operands().first().map(|&v| plan.value_cols(v)).unwrap_or(0);
    let fused = matches!(op, Op::SpmmFusedRelu { .. });
    let inplace = plan.inplace_operand(i).is_some();
    let mut span = obs::Span::enter(name)
        .arg("k", Json::num(k as f64))
        .arg("threads", Json::num(threads as f64))
        .arg("fused", Json::bool(fused))
        .arg("inplace", Json::bool(inplace));
    match op {
        Op::Spmm { .. } | Op::SpmmFusedRelu { .. } => {
            let (kernel, fmt) = match operand.impl_kind {
                SpmmImpl::Kernel => {
                    let c = KernelRegistry::global().resolve(&operand.context, k, Semiring::Sum);
                    (c.label(), c.format_label())
                }
                SpmmImpl::EdgeWise => ("edgewise".to_string(), "coo".to_string()),
                SpmmImpl::Dense => ("dense".to_string(), "dense".to_string()),
            };
            span = span
                .arg("rows", Json::num(operand.a.rows as f64))
                .arg("nnz", Json::num(operand.a.nnz() as f64))
                .arg("shards", Json::num(operand.shards as f64))
                .arg("kernel", Json::str(&kernel))
                .arg("format", Json::str(&fmt))
                .agg(format!("op.{name}{{fmt={fmt},k={k},kernel={kernel},threads={threads}}}"));
        }
        _ => span = span.agg(format!("op.{name}{{k={k},threads={threads}}}")),
    }
    span
}

/// Record the plan's forward pass onto `tape`; returns the logits node.
///
/// `x` is the feature-matrix node and `vars` maps parameter names to their
/// tape handles (the trainer inserts every parameter at the start of each
/// step). This is the training executor: every op lands as a tape node, so
/// [`Tape::backward`] sees exactly the structure the plan describes —
/// including the fused op, whose backward is bitwise-equal to the unfused
/// chain's.
pub fn execute_taped(
    plan: &ExecutionPlan,
    tape: &mut Tape,
    operand: &SpmmOperand,
    x: Var,
    vars: &BTreeMap<String, Var>,
) -> Result<Var> {
    let get = |name: &str| -> Result<Var> {
        vars.get(name).copied().ok_or_else(|| Error::UnknownName(format!("param var '{name}'")))
    };
    let _plan_span = obs::Span::enter("plan.execute_taped")
        .arg("ops", Json::num(plan.ops().len() as f64));
    // the plan's shard lowering stamps onto the operand ONCE per execution
    // — this single line is how training inherits sharding (inference has
    // its twin below); no per-path special cases exist downstream
    let sharded;
    let operand = if operand.shards == plan.shards() {
        operand
    } else {
        sharded = operand.clone().with_shards(plan.shards());
        &sharded
    };
    let mut vals: Vec<Var> = Vec::with_capacity(plan.num_values());
    vals.push(x);
    for (i, op) in plan.ops().iter().enumerate() {
        // taped kernels run on the global pool's full budget
        let _span = instr_span(plan, i, op, operand, parallel::current_num_threads());
        let var = match op {
            Op::Spmm { x } => tape.spmm(operand, vals[*x])?,
            Op::MatMul { x, w } => tape.matmul(vals[*x], get(w)?)?,
            Op::BiasAdd { x, b } => tape.add_bias(vals[*x], get(b)?)?,
            Op::Relu { x } => tape.relu(vals[*x])?,
            Op::Add { a, b } => tape.add(vals[*a], vals[*b])?,
            Op::SpmmFusedRelu { x, bias } => {
                let bias = match bias {
                    Some(name) => Some(get(name)?),
                    None => None,
                };
                tape.spmm_fused_relu(operand, vals[*x], bias)?
            }
        };
        vals.push(var);
    }
    Ok(vals[plan.output()])
}

/// Scratch allocator over the operand's (optional) shared workspace: every
/// intermediate is drawn from and retired into the pool, so a warm
/// execution allocates (almost) nothing. Final outputs are allocated
/// outside the pool — they leave with the caller.
struct Scratch<'a> {
    ws: Option<&'a KernelWorkspace>,
}

impl Scratch<'_> {
    fn alloc(&self, rows: usize, cols: usize) -> Dense {
        match self.ws {
            Some(ws) => ws.take_dense(rows, cols),
            None => Dense::zeros(rows, cols),
        }
    }

    fn free(&self, d: Dense) {
        if let Some(ws) = self.ws {
            ws.recycle(d.data);
        }
    }

    fn free_all(&self, v: Vec<Dense>) {
        for d in v {
            self.free(d);
        }
    }
}

/// One SpMM under the operand's strategy — kernel calls route through the
/// registry per `(context, K)` exactly as the training tape does, with
/// workspace-cached partitions and pooled outputs.
fn spmm_call(operand: &SpmmOperand, x: &Dense, threads: usize) -> Result<Dense> {
    // failpoint: the chaos suite injects panics/errors/delays here, tagged
    // with the operand context (= session name in serving), to fault one
    // tenant's kernels while co-tenants run clean. No-op in normal builds.
    crate::util::failpoints::check("kernels.spmm", &operand.context)?;
    match operand.impl_kind {
        SpmmImpl::Kernel => {
            let choice = KernelRegistry::global().resolve(&operand.context, x.cols, Semiring::Sum);
            let ws = operand.workspace.as_deref().map(|w| (w, operand.graph_key()));
            spmm_sharded(&operand.a, x, Semiring::Sum, choice, threads, ws, operand.shards)
        }
        SpmmImpl::EdgeWise => operand.edgewise_forward(x),
        SpmmImpl::Dense => operand.dense.as_ref().expect("dense operand").matmul(x),
    }
}

/// One fused SpMM+bias+ReLU under the operand's strategy. Kernel operands
/// route the fused family through the registry exactly like the plain one
/// — the tuner's joint `(format, fuse)` decision — so a SELL- or
/// sorted-CSR-tuned session serves fused from its tuned (pre-converted)
/// layout. Baseline strategies aggregate their usual way, then apply the
/// epilogue — same numerics, unfused loops.
fn fused_call(
    operand: &SpmmOperand,
    x: &Dense,
    bias: Option<&[f32]>,
    threads: usize,
) -> Result<Dense> {
    match operand.impl_kind {
        SpmmImpl::Kernel => {
            // same chaos site as the unfused dispatch: one plan covers
            // both aggregation families of a faulted session
            crate::util::failpoints::check("kernels.spmm", &operand.context)?;
            let choice = KernelRegistry::global().resolve(&operand.context, x.cols, Semiring::Sum);
            let ws = operand.workspace.as_deref().map(|w| (w, operand.graph_key()));
            spmm_fused_relu_sharded(&operand.a, x, bias, choice, threads, ws, operand.shards)
        }
        _ => {
            let mut y = spmm_call(operand, x, threads)?;
            fused_relu_epilogue(&mut y, bias)?;
            Ok(y)
        }
    }
}

/// Aggregate every request's panel in **one** kernel call (the micro-batch
/// coalescing), then split the result back per request. A batch of one
/// skips the pack/unpack entirely. `bias`, when present, is tiled across
/// the coalesced panel (into a pooled scratch row, not a fresh allocation)
/// so the fused epilogue applies each request's identical bias —
/// bitwise-equal to per-request execution because every output element is
/// produced by the same scalar ops either way. With `owned` the results
/// land in caller-owned (unpooled) buffers — the plan-output case.
fn aggregate_many(
    operand: &SpmmOperand,
    xs: &[&Dense],
    fused_bias: Option<Option<&[f32]>>,
    threads: usize,
    scratch: &Scratch<'_>,
    owned: bool,
) -> Result<Vec<Dense>> {
    let one = |x: &Dense| match fused_bias {
        Some(bias) => fused_call(operand, x, bias, threads),
        None => spmm_call(operand, x, threads),
    };
    if xs.len() == 1 {
        let y = one(xs[0])?;
        if owned && scratch.ws.is_some() {
            // one copy into a caller-owned buffer; the pooled original
            // goes back to the pool. Without a workspace the kernel
            // output is already a fresh unpooled allocation — hand it to
            // the caller directly instead of copying it.
            let out = y.clone();
            scratch.free(y);
            return Ok(vec![out]);
        }
        return Ok(vec![y]);
    }
    let rows = xs[0].rows;
    let total: usize = xs.iter().map(|x| x.cols).sum();
    let mut packed = scratch.alloc(rows, total);
    concat_cols_into(xs, &mut packed)?;
    let y = match fused_bias {
        None => spmm_call(operand, &packed, threads)?,
        Some(None) => fused_call(operand, &packed, None, threads)?,
        Some(Some(bias)) => {
            let mut tiled = scratch.alloc(1, total);
            for chunk in tiled.data.chunks_mut(bias.len()) {
                chunk.copy_from_slice(bias);
            }
            let out = fused_call(operand, &packed, Some(&tiled.data), threads)?;
            scratch.free(tiled);
            out
        }
    };
    scratch.free(packed);
    // per-request slices split straight into pooled buffers (or
    // caller-owned ones for the plan output — no intermediate copy)
    let mut outs: Vec<Dense> = xs
        .iter()
        .map(|x| {
            if owned {
                Dense::zeros(rows, x.cols)
            } else {
                scratch.alloc(rows, x.cols)
            }
        })
        .collect();
    split_cols_into(&y, &mut outs)?;
    scratch.free(y);
    Ok(outs)
}

/// Execute the plan tape-free for `m` same-graph requests: one logits
/// matrix per request, in request order. This is the inference executor
/// behind [`crate::serve`]:
///
/// * **Explicit thread budget** — `threads` caps the kernel parallelism of
///   every op in this execution (serving passes the per-session budget; 1
///   runs fully inline on the calling thread, touching no pool worker).
/// * **Coalesced aggregation** — at every SpMM point the per-request
///   panels are column-concatenated and aggregated in one kernel call;
///   dense projections/bias/activation stay per-request. Bitwise-equal to
///   per-request execution (asserted in tests and by `serve-bench`).
/// * **No tape, no gradients, no `BackpropCache`** — a serving run leaves
///   `CacheStats` untouched.
/// * **Pooled intermediates** — buffers are drawn from the operand's
///   shared workspace and retired at each value's precomputed last use,
///   so a warm execution cycles through at most
///   [`ExecutionPlan::num_slots`] buffers per request.
pub fn execute_inference(
    plan: &ExecutionPlan,
    operand: &SpmmOperand,
    params: &ParamSet,
    xs: &[&Dense],
    threads: usize,
) -> Result<Vec<Dense>> {
    if xs.is_empty() {
        return Ok(Vec::new());
    }
    let n = operand.a.rows;
    for x in xs {
        if x.rows != n || x.cols != plan.in_dim() {
            return Err(Error::ShapeMismatch(format!(
                "execute_inference: expected {}x{} features, got {}x{}",
                n,
                plan.in_dim(),
                x.rows,
                x.cols
            )));
        }
    }
    let _plan_span = obs::Span::enter("plan.execute_inference")
        .arg("batch", Json::num(xs.len() as f64))
        .arg("threads", Json::num(threads as f64))
        .arg("ops", Json::num(plan.ops().len() as f64));
    // twin of the taped stamp: the plan's shard count reaches every
    // spmm_call/fused_call below through the operand
    let sharded;
    let operand = if operand.shards == plan.shards() {
        operand
    } else {
        sharded = operand.clone().with_shards(plan.shards());
        &sharded
    };
    let scratch = Scratch { ws: operand.workspace.as_deref() };
    let b = xs.len();
    let mut vals: Vec<Option<Vec<Dense>>> = (0..plan.num_values()).map(|_| None).collect();
    // The plan's slot assignment, realised: when a value dies, its buffers
    // park under the value's precomputed slot; the next same-slot (same
    // width, disjoint lifetime — guaranteed by the linear scan) value
    // takes them over directly, with no pool round-trip. The dense `_into`
    // ops overwrite their output completely, so dirty reuse is safe;
    // kernel outputs instead recycle the parked buffers into the pool the
    // dispatch draws zeroed buffers from. At the end everything parked
    // returns to the shared pool for the next execution.
    let mut slots: Vec<Option<Vec<Dense>>> = (0..plan.num_slots()).map(|_| None).collect();

    for (i, op) in plan.ops().iter().enumerate() {
        let out_id = i + 1;
        let is_output = out_id == plan.output();
        let out_slot = plan.slot_of(out_id);
        let _span = instr_span(plan, i, op, operand, threads);
        let outs: Vec<Dense> = match op {
            Op::Spmm { x } | Op::SpmmFusedRelu { x, .. } => {
                let fused_bias = match op {
                    Op::SpmmFusedRelu { bias, .. } => Some(match bias {
                        Some(name) => Some(&params.get(name)?.data[..]),
                        None => None,
                    }),
                    _ => None,
                };
                // the kernel dispatch needs zeroed buffers from the pool —
                // feed it this slot's parked buffers via a recycle
                scratch.free_all(take_slot(&mut slots, out_slot));
                let srcs = value_refs(&vals, xs, *x);
                aggregate_many(operand, &srcs, fused_bias, threads, &scratch, is_output)?
            }
            Op::MatMul { x, w } => {
                let w = params.get(w)?;
                let mut reuse = take_slot(&mut slots, out_slot);
                let srcs = value_refs(&vals, xs, *x);
                let mut outs = Vec::with_capacity(srcs.len());
                for src in srcs {
                    let mut out =
                        next_buf(&mut reuse, &scratch, is_output, src.rows, w.cols);
                    src.matmul_into(w, &mut out)?;
                    outs.push(out);
                }
                scratch.free_all(reuse);
                outs
            }
            // The elementwise ops execute IN PLACE when the plan says their
            // operand dies here (in-place slot execution): the operand's
            // buffers are taken over and overwritten by the `_inplace`
            // kernels — bitwise-equal to the `_into` twins, minus a full
            // matrix write+read per op. The plan output never runs in
            // place (`inplace_operand` is None there), so caller-owned
            // buffers are unaffected.
            Op::BiasAdd { x, b: bias } => {
                let bias = params.get(bias)?;
                if let Some(v) = plan.inplace_operand(i) {
                    debug_assert_eq!(v, *x);
                    let mut bufs = vals[v].take().expect("in-place operand live");
                    for buf in &mut bufs {
                        buf.add_row_broadcast_inplace(&bias.data)?;
                    }
                    bufs
                } else {
                    let mut reuse = take_slot(&mut slots, out_slot);
                    let srcs = value_refs(&vals, xs, *x);
                    let mut outs = Vec::with_capacity(srcs.len());
                    for src in srcs {
                        let mut out =
                            next_buf(&mut reuse, &scratch, is_output, src.rows, src.cols);
                        src.add_row_broadcast_into(&bias.data, &mut out)?;
                        outs.push(out);
                    }
                    scratch.free_all(reuse);
                    outs
                }
            }
            Op::Relu { x } => {
                if let Some(v) = plan.inplace_operand(i) {
                    debug_assert_eq!(v, *x);
                    let mut bufs = vals[v].take().expect("in-place operand live");
                    for buf in &mut bufs {
                        buf.relu_inplace();
                    }
                    bufs
                } else {
                    let mut reuse = take_slot(&mut slots, out_slot);
                    let srcs = value_refs(&vals, xs, *x);
                    let mut outs = Vec::with_capacity(srcs.len());
                    for src in srcs {
                        let mut out =
                            next_buf(&mut reuse, &scratch, is_output, src.rows, src.cols);
                        src.relu_into(&mut out)?;
                        outs.push(out);
                    }
                    scratch.free_all(reuse);
                    outs
                }
            }
            Op::Add { a, b: rhs } => match plan.inplace_operand(i) {
                // the dying LEFT operand is the accumulator: a += b
                Some(v) if v == *a => {
                    let mut bufs = vals[v].take().expect("in-place operand live");
                    let rhs = value_refs(&vals, xs, *rhs);
                    for (buf, r) in bufs.iter_mut().zip(rhs) {
                        buf.add_inplace(r)?;
                    }
                    bufs
                }
                // only the RIGHT operand dies: b = a + b (same addend
                // order as `add_into`, so still bitwise-equal)
                Some(v) => {
                    debug_assert_eq!(v, *rhs);
                    let mut bufs = vals[v].take().expect("in-place operand live");
                    let lhs = value_refs(&vals, xs, *a);
                    for (buf, l) in bufs.iter_mut().zip(lhs) {
                        buf.radd_inplace(l)?;
                    }
                    bufs
                }
                None => {
                    let mut reuse = take_slot(&mut slots, out_slot);
                    let lhs = value_refs(&vals, xs, *a);
                    let rhs = value_refs(&vals, xs, *rhs);
                    let mut outs = Vec::with_capacity(lhs.len());
                    for (l, r) in lhs.iter().zip(rhs.iter()) {
                        let mut out =
                            next_buf(&mut reuse, &scratch, is_output, l.rows, l.cols);
                        l.add_into(r, &mut out)?;
                        outs.push(out);
                    }
                    scratch.free_all(reuse);
                    outs
                }
            },
        };
        debug_assert_eq!(outs.len(), b);
        vals[out_id] = Some(outs);

        // retire every value whose last use this instruction was: its
        // buffers park under its slot for the next same-slot value
        for v in op.operands() {
            if v != INPUT_VALUE && plan.last_use(v) == i {
                if let Some(bufs) = vals[v].take() {
                    park(&mut slots, &scratch, plan.slot_of(v), bufs);
                }
            }
        }
        if !is_output && plan.last_use(out_id) == i {
            // dead code (never produced by lowering, possible in synthetic
            // plans): retire immediately
            if let Some(bufs) = vals[out_id].take() {
                park(&mut slots, &scratch, out_slot, bufs);
            }
        }
    }

    let out = vals[plan.output()].take().expect("plan output computed");
    // parked buffers feed the next execution through the shared pool
    for bufs in slots.into_iter().flatten() {
        scratch.free_all(bufs);
    }
    Ok(out)
}

/// Per-request read access to a value: the borrowed input panels for
/// [`INPUT_VALUE`], the computed buffers otherwise.
fn value_refs<'a>(
    vals: &'a [Option<Vec<Dense>>],
    xs: &'a [&Dense],
    v: ValueId,
) -> Vec<&'a Dense> {
    if v == INPUT_VALUE {
        xs.to_vec()
    } else {
        vals[v].as_ref().expect("plan executes in SSA order").iter().collect()
    }
}

/// Take the buffers parked under a slot (empty when the slot has no dead
/// predecessor yet, or for the unslotted input/output values).
fn take_slot(slots: &mut [Option<Vec<Dense>>], slot: Option<usize>) -> Vec<Dense> {
    slot.and_then(|s| slots[s].take()).unwrap_or_default()
}

/// Park a dead value's buffers under its slot; anything unslotted (or a
/// somehow-occupied slot, which the linear-scan invariant rules out) goes
/// back to the pool instead.
fn park(
    slots: &mut [Option<Vec<Dense>>],
    scratch: &Scratch<'_>,
    slot: Option<usize>,
    bufs: Vec<Dense>,
) {
    match slot {
        Some(s) if slots[s].is_none() => slots[s] = Some(bufs),
        _ => scratch.free_all(bufs),
    }
}

/// The next output buffer for a dense op: a parked same-slot buffer
/// (dirty — the `_into` ops overwrite completely), else pooled, else (for
/// the plan output) caller-owned.
fn next_buf(
    reuse: &mut Vec<Dense>,
    scratch: &Scratch<'_>,
    is_output: bool,
    rows: usize,
    cols: usize,
) -> Dense {
    if let Some(buf) = reuse.pop() {
        debug_assert_eq!((buf.rows, buf.cols), (rows, cols));
        return buf;
    }
    if is_output {
        Dense::zeros(rows, cols)
    } else {
        scratch.alloc(rows, cols)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::karate_club;
    use crate::gnn::{GnnModel, ModelParams};
    use crate::util::rng::Rng;
    use std::sync::Arc;

    fn setup(model: GnnModel) -> (ExecutionPlan, SpmmOperand, ParamSet, usize) {
        let ds = karate_club();
        let dims = ModelParams { in_dim: ds.feature_dim(), hidden: 8, classes: ds.num_classes };
        let plan = model.lower(dims, model.norm_kind());
        let params = model.init_params(dims, 7);
        let a = model.norm_kind().apply(&ds.adj).unwrap();
        let n = a.rows;
        let ws = Arc::new(KernelWorkspace::new());
        let operand = SpmmOperand::uncached(a, "plan-exec-test")
            .with_workspace(ws, crate::autodiff::context_graph_id("plan-exec-test"));
        (plan, operand, params, n)
    }

    #[test]
    fn taped_and_inference_agree_bitwise() {
        for model in GnnModel::ALL {
            let (plan, operand, params, n) = setup(model);
            let mut rng = Rng::seed_from_u64(51);
            let x = Dense::uniform(n, plan.in_dim(), 1.0, &mut rng);
            let inf = execute_inference(&plan, &operand, &params, &[&x], 1).unwrap();
            let mut tape = Tape::new(1);
            let xv = tape.input(x.clone());
            let mut vars = BTreeMap::new();
            for (name, value) in params.iter() {
                vars.insert(name.clone(), tape.input(value.clone()));
            }
            let logits = execute_taped(&plan, &mut tape, &operand, xv, &vars).unwrap();
            assert_eq!(inf[0].data, tape.value(logits).data, "{model:?}");
            assert_eq!(inf[0].rows, n, "{model:?}");
            assert_eq!(inf[0].cols, plan.dims().classes, "{model:?}");
        }
    }

    #[test]
    fn batched_inference_is_bitwise_equal_to_solo() {
        for model in GnnModel::ALL {
            let (plan, operand, params, n) = setup(model);
            let mut rng = Rng::seed_from_u64(52);
            let xs: Vec<Dense> =
                (0..5).map(|_| Dense::uniform(n, plan.in_dim(), 1.0, &mut rng)).collect();
            let refs: Vec<&Dense> = xs.iter().collect();
            let batched = execute_inference(&plan, &operand, &params, &refs, 2).unwrap();
            assert_eq!(batched.len(), 5, "{model:?}");
            for (x, got) in xs.iter().zip(&batched) {
                let solo = execute_inference(&plan, &operand, &params, &[x], 2).unwrap();
                assert_eq!(solo[0].data, got.data, "{model:?}: batched diverged");
            }
        }
    }

    #[test]
    fn fused_plan_inference_is_bitwise_equal_to_unfused() {
        let (plan, operand, params, n) = setup(GnnModel::Gcn);
        let fused = plan.fuse_spmm_relu(|_| true);
        assert_eq!(fused.fused_op_count(), 1);
        let mut rng = Rng::seed_from_u64(53);
        let xs: Vec<Dense> =
            (0..3).map(|_| Dense::uniform(n, plan.in_dim(), 1.0, &mut rng)).collect();
        let refs: Vec<&Dense> = xs.iter().collect();
        for threads in [1usize, 3] {
            let want = execute_inference(&plan, &operand, &params, &refs, threads).unwrap();
            let got = execute_inference(&fused, &operand, &params, &refs, threads).unwrap();
            for (w, g) in want.iter().zip(&got) {
                assert_eq!(w.data, g.data, "threads={threads}");
            }
        }
    }

    #[test]
    fn sharded_plan_is_bitwise_equal_on_both_executors() {
        for model in GnnModel::ALL {
            let (plan, operand, params, n) = setup(model);
            let mut rng = Rng::seed_from_u64(56);
            let x = Dense::uniform(n, plan.in_dim(), 1.0, &mut rng);
            let flat = execute_inference(&plan, &operand, &params, &[&x], 2).unwrap();
            for shards in [2usize, 4] {
                let sharded_plan = plan.clone().with_shards(shards);
                let got =
                    execute_inference(&sharded_plan, &operand, &params, &[&x], 2).unwrap();
                assert_eq!(flat[0].data, got[0].data, "{model:?} shards={shards} inference");
                // and the taped executor inherits the same lowering
                let mut tape = Tape::new(2);
                let xv = tape.input(x.clone());
                let mut vars = BTreeMap::new();
                for (name, value) in params.iter() {
                    vars.insert(name.clone(), tape.input(value.clone()));
                }
                let logits =
                    execute_taped(&sharded_plan, &mut tape, &operand, xv, &vars).unwrap();
                assert_eq!(flat[0].data, tape.value(logits).data, "{model:?} shards={shards} taped");
            }
        }
    }

    #[test]
    fn empty_batch_is_empty() {
        let (plan, operand, params, _) = setup(GnnModel::Gcn);
        assert!(execute_inference(&plan, &operand, &params, &[], 1).unwrap().is_empty());
    }

    #[test]
    fn shape_mismatch_rejected() {
        let (plan, operand, params, n) = setup(GnnModel::Gcn);
        let wrong_cols = Dense::zeros(n, plan.in_dim() + 1);
        assert!(execute_inference(&plan, &operand, &params, &[&wrong_cols], 1).is_err());
        let wrong_rows = Dense::zeros(n + 1, plan.in_dim());
        assert!(execute_inference(&plan, &operand, &params, &[&wrong_rows], 1).is_err());
    }

    #[test]
    fn missing_param_errors() {
        let (plan, operand, _, n) = setup(GnnModel::Gcn);
        let empty = ParamSet::new();
        let x = Dense::zeros(n, plan.in_dim());
        assert!(execute_inference(&plan, &operand, &empty, &[&x], 1).is_err());
        // taped executor surfaces the same error for a missing var
        let mut tape = Tape::new(1);
        let xv = tape.input(x);
        let vars = BTreeMap::new();
        assert!(execute_taped(&plan, &mut tape, &operand, xv, &vars).is_err());
    }

    #[test]
    fn inference_emits_instruction_spans_and_aggregates() {
        let _guard = crate::obs::ObsGuard::tracing();
        crate::obs::clear_trace();
        let (plan, operand, params, n) = setup(GnnModel::Gcn);
        let mut rng = Rng::seed_from_u64(55);
        let x = Dense::uniform(n, plan.in_dim(), 1.0, &mut rng);
        execute_inference(&plan, &operand, &params, &[&x], 1).unwrap();
        let doc = crate::obs::trace_json();
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let has = |name: &str| {
            events.iter().any(|e| {
                e.get("name").ok().and_then(|v| v.as_str().ok()).map(|s| s == name).unwrap_or(false)
            })
        };
        assert!(has("plan.execute_inference"), "missing executor span");
        assert!(has("spmm"), "missing spmm instruction span");
        assert!(has("matmul"), "missing matmul instruction span");
        // the aggregate table picked up a per-op labelled histogram
        let snap = crate::obs::snapshot();
        let hists = snap.get("histograms").unwrap();
        let has_spmm_agg = match hists {
            Json::Obj(m) => m.keys().any(|k| k.starts_with("op.spmm{")),
            _ => false,
        };
        assert!(has_spmm_agg, "missing op.spmm aggregate: {}", hists.compact());
        crate::obs::clear_trace();
    }

    #[test]
    fn warm_execution_reuses_workspace_buffers() {
        let (plan, operand, params, n) = setup(GnnModel::Gcn);
        let ws = Arc::clone(operand.workspace.as_ref().unwrap());
        let mut rng = Rng::seed_from_u64(54);
        let xs: Vec<Dense> =
            (0..3).map(|_| Dense::uniform(n, plan.in_dim(), 1.0, &mut rng)).collect();
        let refs: Vec<&Dense> = xs.iter().collect();
        let first = execute_inference(&plan, &operand, &params, &refs, 2).unwrap();
        let allocs_after_first = ws.stats().buffer_allocs;
        let second = execute_inference(&plan, &operand, &params, &refs, 2).unwrap();
        for (a, b) in first.iter().zip(&second) {
            assert_eq!(a.data, b.data);
        }
        let stats = ws.stats();
        // the second batch runs on retired-at-last-use buffers — the
        // precomputed lifetimes keep the pool population at the slot bound
        assert!(stats.buffer_reuses > 0, "{stats:?}");
        assert!(
            stats.buffer_allocs <= allocs_after_first + 2,
            "second batch re-allocated: {stats:?}"
        );
        assert!(stats.partition_hits > 0, "{stats:?}");
    }
}
