//! The shared ExecutionPlan IR — **one lowering, every execution path**.
//!
//! Before this subsystem the repo carried three hand-written GNN forwards
//! that had to be kept bitwise-consistent by hand: the tape-recording
//! training forward, the cache-free serving forward, and the AOT HLO step.
//! `plan` replaces the first two (the HLO step stays a compiled artifact)
//! with a single lowering point, the way DGL lowers message passing to a
//! small g-SpMM op set so cross-path optimisation becomes tractable:
//!
//! * **IR** ([`ExecutionPlan`], [`Op`]) — a small SSA-style op graph.
//!   Value 0 is the feature matrix; instruction `i` defines value `i + 1`;
//!   the last instruction defines the logits. Parameters are referenced by
//!   name (the [`ParamSet`](crate::gnn::ParamSet) keys), so one plan
//!   serves any parameter values — train-time or frozen. Alongside the
//!   ops, the plan precomputes **value lifetimes** (`last_use`) and a
//!   linear-scan **slot assignment** mapping values of equal width onto
//!   shared size-class slots. The inference executor realises the
//!   assignment directly: a dying value's buffers park under its slot and
//!   the next same-slot value takes them over without touching the
//!   [`KernelWorkspace`](crate::kernels::KernelWorkspace) pool (kernel
//!   outputs recycle through the pool instead, which draws zeroed
//!   buffers), so a warm serving batch cycles through at most
//!   [`ExecutionPlan::num_slots`] buffers per request and allocates
//!   (almost) nothing. The training executor records values onto the tape
//!   — they must all outlive the backward sweep — and the tape recycles
//!   them into the same pool on drop.
//!
//!   **In-place slot rules** ([`ExecutionPlan::inplace_operand`]): an op
//!   may reuse an operand's slot — and, in the inference executor, its
//!   actual buffer, via the `Dense::{relu,add_row_broadcast,add,radd}_inplace`
//!   kernels instead of a `_into` copy — exactly when ALL of these hold:
//!
//!   1. the op is **elementwise** (`Relu`, `BiasAdd`, `Add`): element
//!      `t` of the output depends only on element `t` of the operand, so
//!      overwriting as it reads is sound. Kernel-backed ops (`Spmm`,
//!      `MatMul`, `SpmmFusedRelu`) never qualify — they need a zeroed
//!      output and read their operand throughout the call;
//!   2. the operand **dies at this instruction** (`last_use == i`): no
//!      later reader observes the overwrite;
//!   3. the operand is not the plan **input** (caller-owned, may be
//!      shared) and the op does not define the plan **output** (which
//!      must leave in a caller-owned, unpooled buffer);
//!   4. for `Add`, the two operands are distinct values (either side may
//!      be the accumulator; the left is preferred).
//!
//!   Future ops opt in by extending the candidate match in
//!   `PlanBuilder::finish` — an op that reads element `t` of its operand
//!   after writing element `u ≠ t` (anything with a reduction, a
//!   broadcast over rows, or a neighbour gather) must NOT be added. The
//!   in-place kernels are property-tested bitwise-equal to their `_into`
//!   twins, so the rewrite never changes numerics; it cuts one full
//!   `n × K` write+read per eligible op in steady state.
//! * **Lowering** ([`GnnModel::lower`](crate::gnn::GnnModel)) — each model
//!   of the zoo lowers to the op set `{Spmm, MatMul, BiasAdd, Relu, Add}`
//!   in exactly the dataflow the deleted hand-written forwards had, so
//!   numerics are unchanged by construction.
//! * **Fusion pass** ([`ExecutionPlan::fuse_spmm_relu`]) — rewrites
//!   `Spmm→Relu` and `Spmm→BiasAdd→Relu` single-consumer chains into the
//!   FusedMM-backed [`Op::SpmmFusedRelu`]
//!   ([`spmm_fused_relu`](crate::kernels::spmm_fused_relu)), eliminating
//!   up to two full passes over the `n × K` activation per layer.
//!   **Invariant: fusion never changes numerics.** The fused kernel
//!   accumulates in the same per-element non-zero-stream order as every
//!   kernel family and applies exactly the unfused epilogue's scalar ops,
//!   so fused and unfused plans are bitwise-equal — property-tested across
//!   all kernel families and sparse formats. Which edges to rewrite is a
//!   *tuning* decision: the pass takes a per-width profitability predicate
//!   fed from the [`TuningDb`](crate::autotune::TuningDb)'s measured
//!   `fuse_relu` entries (or a policy override), so fusion only happens
//!   where it measured faster.
//! * **Sharded lowering** ([`ExecutionPlan::with_shards`]) — the shard
//!   count is a property of the *plan*, not of a call site. The rules:
//!
//!   1. a plan carries `shards` (default 1 = flat); the serving registry
//!      sets it from the tuner's warm-started shard decision
//!      ([`TuningDb::shard_count`](crate::autotune::TuningDb::shard_count)),
//!      and [`fuse_spmm_relu`](ExecutionPlan::fuse_spmm_relu) preserves it
//!      across the rewrite;
//!   2. both executors stamp the count onto the
//!      [`SpmmOperand`](crate::autodiff::SpmmOperand) once per execution,
//!      so every aggregation op — plain or fused, forward or backward —
//!      routes through [`spmm_sharded`](crate::kernels::spmm_sharded) /
//!      [`spmm_fused_relu_sharded`](crate::kernels::spmm_fused_relu_sharded)
//!      with the same count. Training, tape-free inference and serving
//!      inherit sharding from this one stamp — no per-path special cases;
//!   3. sharded execution is **bitwise-equal** to flat for values and
//!      gradients (the gathered-panel construction in
//!      [`crate::kernels::shard`] renames columns without reordering any
//!      per-row non-zero stream), so `shards` is purely a performance
//!      knob: shard-local workspace state (cached partitions, SELL /
//!      sorted-CSR conversions) retires with the plan's `(graph, epoch)`
//!      key exactly like every other cached artifact.
//! * **Executors** — two thin interpreters over the same plan:
//!   [`execute_taped`] records the ops onto the autodiff
//!   [`Tape`](crate::autodiff::Tape) (cache-enabled backprop; the
//!   [`Trainer`](crate::train::Trainer) consumes it), and
//!   [`execute_inference`] runs tape-free with an **explicit thread
//!   budget** (so serving can cap per-session parallelism), coalescing
//!   same-graph requests into one SpMM per aggregation point exactly as
//!   the serving batcher requires. Both paths execute the identical op
//!   list, so "training forward == serving forward" is a structural fact,
//!   not a test-enforced convention.
//!
//! The tuner consumes [`ExecutionPlan::spmm_shapes`] (and its batched
//! variant) instead of hand-maintained per-model width lists: whatever the
//! plan will execute is, by definition, what gets tuned.

mod exec;
mod fuse;
mod ir;
mod lower;

pub use exec::{execute_inference, execute_taped};
pub use ir::{ExecutionPlan, Op, ValueId, INPUT_VALUE};
