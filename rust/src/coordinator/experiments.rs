//! Experiment orchestration: the code that regenerates every table and
//! figure of the paper (DESIGN.md §6 maps ids → functions here).
//!
//! * [`table1_rows`] — Table 1 (dataset inventory, paper-scale + generated).
//! * [`figure2_sweep`] — Figure 2 (tuning graphs per dataset × CPU profile).
//! * [`figure3_grid`] — Figure 3 (per-epoch training time, model × dataset
//!   × framework, plus speedup-vs-PT2 summary — the headline 27×/12×/8×/18×
//!   numbers fall out of this grid's max over datasets).

use crate::autotune::{HardwareProfile, TuneConfig, Tuner, TuningReport};
use crate::data::{paper_specs, Dataset, DatasetSpec};
use crate::error::Result;
use crate::gnn::GnnModel;
use crate::train::{Backend, TrainConfig, Trainer};

/// Shared experiment knobs (scaled-down instantiation, see DESIGN.md §5).
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Node-count divisor vs the paper-scale specs.
    pub scale: usize,
    /// RNG seed for generators.
    pub seed: u64,
    /// Epochs per training run.
    pub epochs: usize,
    /// Hidden width.
    pub hidden: usize,
    /// Kernel thread budget.
    pub threads: usize,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig { scale: 256, seed: 7, epochs: 10, hidden: 32, threads: 1 }
    }
}

impl ExperimentConfig {
    /// Tiny settings for tests.
    pub fn quick() -> Self {
        ExperimentConfig { scale: 4096, seed: 7, epochs: 3, hidden: 16, threads: 1 }
    }
}

/// One Table 1 row: the paper-scale spec and the generated instantiation.
#[derive(Clone, Debug)]
pub struct Table1Row {
    /// Dataset name.
    pub name: String,
    /// Feature dim (paper column "Feature count").
    pub feature_dim: usize,
    /// Classes (paper column "Prediction class").
    pub classes: usize,
    /// Paper-scale node count.
    pub paper_nodes: usize,
    /// Paper-scale edge count.
    pub paper_edges: usize,
    /// Generated node count at this run's scale.
    pub gen_nodes: usize,
    /// Generated (directed) edge count.
    pub gen_edges: usize,
    /// Generated average degree (should track paper avg degree).
    pub gen_avg_degree: f64,
}

/// Regenerate Table 1: specs + what the generators actually produced.
pub fn table1_rows(cfg: &ExperimentConfig) -> Result<Vec<Table1Row>> {
    let mut rows = Vec::new();
    for spec in paper_specs() {
        let ds = spec.instantiate(cfg.scale, cfg.seed)?;
        rows.push(Table1Row {
            name: spec.name.clone(),
            feature_dim: spec.feature_dim,
            classes: spec.num_classes,
            paper_nodes: spec.paper_nodes,
            paper_edges: spec.paper_edges,
            gen_nodes: ds.num_nodes(),
            gen_edges: ds.num_edges(),
            gen_avg_degree: ds.num_edges() as f64 / ds.num_nodes() as f64,
        });
    }
    Ok(rows)
}

/// Format Table 1 as an aligned text table.
pub fn render_table1(rows: &[Table1Row]) -> String {
    let mut out = String::from(
        "dataset          feat  cls  paper_nodes  paper_edges    gen_nodes  gen_edges  gen_avgdeg\n",
    );
    for r in rows {
        out.push_str(&format!(
            "{:<16} {:>4} {:>4} {:>12} {:>12} {:>11} {:>10} {:>10.2}\n",
            r.name,
            r.feature_dim,
            r.classes,
            r.paper_nodes,
            r.paper_edges,
            r.gen_nodes,
            r.gen_edges,
            r.gen_avg_degree
        ));
    }
    out
}

/// Regenerate Figure 2: one tuning curve per (dataset, CPU profile).
/// `profiles` is typically `["intel-skylake", "amd-epyc"]` (the paper's two
/// testbeds) or `["host"]`.
pub fn figure2_sweep(
    cfg: &ExperimentConfig,
    datasets: &[DatasetSpec],
    profiles: &[&str],
    ks: &[usize],
) -> Result<Vec<TuningReport>> {
    let mut reports = Vec::new();
    for profile_name in profiles {
        let profile = HardwareProfile::named(profile_name)?;
        let tuner = Tuner::with_config(
            profile,
            TuneConfig { ks: ks.to_vec(), reps: 3, warmup: 1, threads: cfg.threads },
        );
        for spec in datasets {
            let ds = spec.instantiate(cfg.scale, cfg.seed)?;
            reports.push(tuner.sweep(&spec.name, &ds.adj)?);
        }
    }
    Ok(reports)
}

/// One Figure 3 cell: `(model, dataset, framework)` → avg per-epoch time.
#[derive(Clone, Debug)]
pub struct Figure3Cell {
    /// Model name.
    pub model: String,
    /// Dataset name.
    pub dataset: String,
    /// Framework (backend label: iSpLib / PT2 / PT1 / PT2-MP / Dense).
    pub framework: String,
    /// Average per-epoch training time (seconds).
    pub avg_epoch_secs: f64,
    /// Final training loss (sanity: all frameworks must agree).
    pub final_loss: f32,
    /// Speedup of iSpLib over this framework (filled by the grid runner).
    pub speedup_vs_isplib: f64,
}

/// Run the Figure 3 grid over `models × datasets × backends`.
///
/// Per dataset+model, iSpLib's time is the denominator of each framework's
/// `speedup_vs_isplib` — the quantity the paper reports above every bar.
pub fn figure3_grid(
    cfg: &ExperimentConfig,
    models: &[GnnModel],
    datasets: &[DatasetSpec],
    backends: &[Backend],
) -> Result<Vec<Figure3Cell>> {
    let mut cells = Vec::new();
    for spec in datasets {
        let ds = spec.instantiate(cfg.scale, cfg.seed)?;
        for &model in models {
            let mut isplib_time = None;
            let mut group = Vec::new();
            for &backend in backends {
                let report = run_cell(cfg, model, backend, &ds)?;
                if backend == Backend::NativeTuned {
                    isplib_time = Some(report.avg_epoch_secs());
                }
                group.push(Figure3Cell {
                    model: model.name().to_string(),
                    dataset: spec.name.clone(),
                    framework: report.backend.clone(),
                    avg_epoch_secs: report.avg_epoch_secs(),
                    final_loss: report.final_loss,
                    speedup_vs_isplib: 0.0,
                });
            }
            if let Some(t_isplib) = isplib_time {
                for cell in &mut group {
                    if t_isplib > 0.0 {
                        cell.speedup_vs_isplib = cell.avg_epoch_secs / t_isplib;
                    }
                }
            }
            cells.extend(group);
        }
    }
    Ok(cells)
}

fn run_cell(
    cfg: &ExperimentConfig,
    model: GnnModel,
    backend: Backend,
    ds: &Dataset,
) -> Result<crate::train::TrainReport> {
    let tc = TrainConfig {
        epochs: cfg.epochs,
        hidden: cfg.hidden,
        threads: cfg.threads,
        ..TrainConfig::default()
    };
    let mut trainer = Trainer::new(model, backend, tc, ds)?;
    trainer.fit(ds)
}

/// Format the Figure 3 grid as a table grouped by (dataset, model).
pub fn render_figure3(cells: &[Figure3Cell]) -> String {
    let mut out = String::from(
        "dataset          model      framework    epoch_secs   speedup_vs_iSpLib  final_loss\n",
    );
    for c in cells {
        out.push_str(&format!(
            "{:<16} {:<10} {:<12} {:>10.6} {:>14.2}x {:>11.4}\n",
            c.dataset, c.model, c.framework, c.avg_epoch_secs, c.speedup_vs_isplib, c.final_loss
        ));
    }
    out
}

/// JSON form of a Figure 3 grid.
pub fn figure3_to_json(cells: &[Figure3Cell]) -> crate::util::json::Json {
    use crate::util::json::Json;
    Json::Arr(
        cells
            .iter()
            .map(|c| {
                Json::obj(vec![
                    ("model", Json::str(&c.model)),
                    ("dataset", Json::str(&c.dataset)),
                    ("framework", Json::str(&c.framework)),
                    ("avg_epoch_secs", Json::num(c.avg_epoch_secs)),
                    ("final_loss", Json::num(c.final_loss as f64)),
                    ("speedup_vs_isplib", Json::num(c.speedup_vs_isplib)),
                ])
            })
            .collect(),
    )
}

/// Headline summary (§5 / abstract): per model, the max speedup of iSpLib
/// over the PT2 framework across datasets.
pub fn headline_speedups(cells: &[Figure3Cell]) -> Vec<(String, f64)> {
    let mut out: Vec<(String, f64)> = Vec::new();
    for c in cells {
        if c.framework != "PT2" {
            continue;
        }
        match out.iter_mut().find(|(m, _)| *m == c.model) {
            Some((_, best)) => *best = best.max(c.speedup_vs_isplib),
            None => out.push((c.model.clone(), c.speedup_vs_isplib)),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::spec_by_name;

    #[test]
    fn table1_has_six_rows_and_degrees_track() {
        let rows = table1_rows(&ExperimentConfig::quick()).unwrap();
        assert_eq!(rows.len(), 6);
        for r in &rows {
            let paper_deg = r.paper_edges as f64 / r.paper_nodes as f64;
            // target degree is the paper's, capped by what the scaled node
            // count can host (see DatasetSpec::instantiate)
            let target = paper_deg.min(r.gen_nodes as f64 / 4.0);
            // R-MAT dedup eats edges on heavy-tailed graphs at small scale;
            // generated degree must still be within 3.3x of the target
            assert!(
                r.gen_avg_degree > target * 0.3 && r.gen_avg_degree < target * 2.0,
                "{}: target {target:.1} vs gen {:.1}",
                r.name,
                r.gen_avg_degree
            );
        }
        let text = render_table1(&rows);
        assert!(text.contains("reddit"));
        assert!(text.contains("ogbn-protein"));
    }

    #[test]
    fn figure2_one_report_per_dataset_profile() {
        let cfg = ExperimentConfig::quick();
        let specs = vec![spec_by_name("ogbn-protein").unwrap()];
        let reports =
            figure2_sweep(&cfg, &specs, &["intel-skylake", "amd-epyc"], &[16, 32]).unwrap();
        assert_eq!(reports.len(), 2);
        for r in &reports {
            assert_eq!(r.points.len(), 2);
        }
    }

    #[test]
    fn figure3_grid_small() {
        let cfg = ExperimentConfig::quick();
        let specs = vec![spec_by_name("ogbn-protein").unwrap()];
        let cells = figure3_grid(
            &cfg,
            &[GnnModel::Gcn],
            &specs,
            &[Backend::NativeTuned, Backend::NativeTrusted],
        )
        .unwrap();
        assert_eq!(cells.len(), 2);
        // all frameworks converge to comparable loss (drop-in claim)
        let l0 = cells[0].final_loss;
        for c in &cells {
            assert!((c.final_loss - l0).abs() < 0.15, "loss drift: {cells:?}");
        }
        // iSpLib's own speedup entry is 1.0 by construction
        let isp = cells.iter().find(|c| c.framework == "iSpLib").unwrap();
        assert!((isp.speedup_vs_isplib - 1.0).abs() < 1e-9);
        let text = render_figure3(&cells);
        assert!(text.contains("iSpLib"));
        let heads = headline_speedups(&cells);
        assert_eq!(heads.len(), 1);
    }
}
