//! `patch()` / `unpatch()` — the paper's §3.6 two-line integration.
//!
//! In iSpLib the user writes
//!
//! ```python
//! import isplib
//! isplib.patch()          # all torch_sparse matmuls now hit iSpLib
//! ...existing PyG code...
//! isplib.unpatch()        # back to stock kernels
//! ```
//!
//! Here the same seam is the global [`KernelRegistry`]: `patch()` engages
//! tuned-kernel routing for every SpMM issued through the autodiff tape
//! (i.e. every trainer in the process), `unpatch()` reverts all of them to
//! the trusted kernel — no trainer code changes, exactly the drop-in
//! semantics the paper advertises. A [`PatchGuard`] offers the RAII form.

use crate::autotune::KernelRegistry;

/// Engage iSpLib kernel routing process-wide.
pub fn patch() {
    KernelRegistry::global().set_patched(true);
}

/// Disengage iSpLib: every SpMM goes back to the trusted kernel.
pub fn unpatch() {
    KernelRegistry::global().set_patched(false);
}

/// Is routing currently engaged?
pub fn is_patched() -> bool {
    KernelRegistry::global().patched()
}

/// RAII guard: patches on construction, unpatches on drop — the analogue
/// of the paper's single-function decorator form.
pub struct PatchGuard(());

impl PatchGuard {
    /// Patch until the guard drops.
    pub fn new() -> Self {
        patch();
        PatchGuard(())
    }
}

impl Default for PatchGuard {
    fn default() -> Self {
        Self::new()
    }
}

impl Drop for PatchGuard {
    fn drop(&mut self) {
        unpatch();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autotune::RegistryEntry;
    use crate::kernels::{KernelChoice, Semiring};
    use std::sync::Mutex;

    // patch state is process-global; serialise the tests that touch it
    static LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn patch_unpatch_toggle() {
        let _g = LOCK.lock().unwrap();
        unpatch();
        assert!(!is_patched());
        patch();
        assert!(is_patched());
        unpatch();
        assert!(!is_patched());
    }

    #[test]
    fn patch_idempotent() {
        let _g = LOCK.lock().unwrap();
        patch();
        patch();
        assert!(is_patched());
        unpatch();
        unpatch();
        assert!(!is_patched());
    }

    #[test]
    fn guard_unpatches_on_drop() {
        let _g = LOCK.lock().unwrap();
        unpatch();
        {
            let _p = PatchGuard::new();
            assert!(is_patched());
        }
        assert!(!is_patched());
    }

    #[test]
    fn unpatched_routing_ignores_bindings() {
        let _g = LOCK.lock().unwrap();
        let reg = KernelRegistry::global();
        reg.bind("patch-test", 64, Semiring::Sum, RegistryEntry {
            choice: KernelChoice::Generated { kb: 16 },
            speedup: 2.0,
        });
        patch();
        assert_eq!(
            reg.resolve("patch-test", 64, Semiring::Sum),
            KernelChoice::Generated { kb: 16 }
        );
        unpatch();
        assert_eq!(reg.resolve("patch-test", 64, Semiring::Sum), KernelChoice::Trusted);
    }
}
