//! The coordinator: experiment orchestration, the `patch()`/`unpatch()`
//! integration seam, and the report emitters that regenerate the paper's
//! tables and figures.

pub mod experiments;
pub mod patch;

pub use experiments::{
    figure2_sweep, figure3_grid, figure3_to_json, headline_speedups, render_figure3,
    render_table1, table1_rows, ExperimentConfig, Figure3Cell, Table1Row,
};
