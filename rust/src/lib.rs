//! # iSpLib — auto-tuned sparse operations for GNN training
//!
//! A Rust + JAX + Pallas reproduction of *iSpLib: A Library for Accelerating
//! Graph Neural Networks using Auto-tuned Sparse Operations* (WWW 2024).
//!
//! The library is organised in three layers:
//!
//! * **L3 (this crate)** — the coordinator: sparse substrate, the
//!   trusted/generated kernel families, the auto-tuner, the backprop cache,
//!   a reverse-mode autodiff tape, the GNN zoo, the shared ExecutionPlan IR
//!   ([`plan`]) that training and serving both execute, the trainer,
//!   dataset generators, the batched multi-graph inference server
//!   ([`serve`]), and the experiment harness that regenerates every table
//!   and figure of the paper.
//! * **L2 (python/compile)** — JAX models (GCN/SAGE/GIN) AOT-lowered to HLO
//!   text, loaded and executed from Rust through [`runtime`] (PJRT).
//! * **L1 (python/compile/kernels)** — Pallas SpMM/SDDMM/FusedMM kernels
//!   called by the L2 models.
//!
//! ## Quickstart
//!
//! ```no_run
//! use isplib::prelude::*;
//!
//! // Build a graph, a model, and train — two extra lines (`patch`) route
//! // all SpMM through the auto-tuned kernels, exactly the paper's §3.6.
//! let dataset = isplib::data::karate_club();
//! isplib::patch();
//! let cfg = TrainConfig { epochs: 50, ..TrainConfig::default() };
//! let mut trainer = Trainer::new(GnnModel::Gcn, Backend::NativeTuned, cfg, &dataset).unwrap();
//! let report = trainer.fit(&dataset).unwrap();
//! println!("final loss {:.4}", report.final_loss);
//! isplib::unpatch();
//! ```

pub mod autodiff;
pub mod autotune;
pub mod cache;
pub mod coordinator;
pub mod data;
pub mod dense;
pub mod error;
pub mod gnn;
pub mod kernels;
pub mod obs;
pub mod plan;
pub mod runtime;
pub mod serve;
pub mod sparse;
pub mod train;
pub mod util;

pub use coordinator::patch::{is_patched, patch, unpatch};

/// Convenience re-exports for downstream users and the examples.
pub mod prelude {
    pub use crate::autotune::{HardwareProfile, Tuner, TuningReport};
    pub use crate::cache::BackpropCache;
    pub use crate::coordinator::patch::{is_patched, patch, unpatch};
    pub use crate::data::{Dataset, DatasetSpec};
    pub use crate::dense::Dense;
    pub use crate::error::{Error, Result};
    pub use crate::gnn::GnnModel;
    pub use crate::kernels::{spmm, EdgeOp, KernelChoice, KernelWorkspace, Semiring};
    pub use crate::plan::ExecutionPlan;
    pub use crate::serve::{InferenceServer, ServeConfig, SessionId};
    pub use crate::sparse::{Coo, Csc, Csr, NormKind};
    pub use crate::train::{Backend, TrainCheckpoint, TrainConfig, TrainReport, Trainer};
}
