//! CSR (compressed sparse row) — the kernel input format (paper §3.5).
//!
//! All SpMM/SDDMM/FusedMM kernels consume this type. Invariants (checked by
//! [`Csr::validate`], relied on by the `unsafe`-free but bounds-hot kernels):
//!
//! 1. `row_ptr.len() == rows + 1`, `row_ptr[0] == 0`, monotone non-decreasing,
//!    `row_ptr[rows] == nnz`.
//! 2. `col_idx[i] < cols` for all `i`.
//! 3. Column indices are sorted strictly increasing within each row (no
//!    duplicates) — the construction path via [`super::Coo::to_csr`]
//!    guarantees this.
//! 4. All values are finite (no NaN/Inf): one bad edge weight would
//!    otherwise poison every output element its row touches. The serving
//!    registry re-validates untrusted graphs at
//!    [`SessionRegistry::register`](crate::serve::SessionRegistry::register)
//!    against exactly these invariants.

use crate::dense::Dense;
use crate::error::{Error, Result};

use super::{Coo, Csc};

/// Compressed-sparse-row matrix with `f32` values.
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    /// Number of rows.
    pub rows: usize,
    /// Number of columns.
    pub cols: usize,
    /// Row offsets, length `rows + 1`.
    pub row_ptr: Vec<usize>,
    /// Column index per non-zero.
    pub col_idx: Vec<usize>,
    /// Value per non-zero.
    pub values: Vec<f32>,
}

impl Csr {
    /// Build from raw parts, validating every invariant.
    pub fn from_parts(
        rows: usize,
        cols: usize,
        row_ptr: Vec<usize>,
        col_idx: Vec<usize>,
        values: Vec<f32>,
    ) -> Result<Self> {
        let m = Csr { rows, cols, row_ptr, col_idx, values };
        m.validate()?;
        Ok(m)
    }

    /// Build from raw parts without validation — for internal construction
    /// paths that guarantee the invariants (e.g. [`Coo::to_csr`]).
    pub(crate) fn from_parts_unchecked(
        rows: usize,
        cols: usize,
        row_ptr: Vec<usize>,
        col_idx: Vec<usize>,
        values: Vec<f32>,
    ) -> Self {
        Csr { rows, cols, row_ptr, col_idx, values }
    }

    /// An identity-free empty matrix.
    pub fn empty(rows: usize, cols: usize) -> Self {
        Csr { rows, cols, row_ptr: vec![0; rows + 1], col_idx: Vec::new(), values: Vec::new() }
    }

    /// Identity matrix (used for self-loop insertion tests).
    pub fn identity(n: usize) -> Self {
        Csr {
            rows: n,
            cols: n,
            row_ptr: (0..=n).collect(),
            col_idx: (0..n).collect(),
            values: vec![1.0; n],
        }
    }

    /// Number of non-zeros.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Column indices of row `r`.
    #[inline]
    pub fn row_cols(&self, r: usize) -> &[usize] {
        &self.col_idx[self.row_ptr[r]..self.row_ptr[r + 1]]
    }

    /// Values of row `r`.
    #[inline]
    pub fn row_vals(&self, r: usize) -> &[f32] {
        &self.values[self.row_ptr[r]..self.row_ptr[r + 1]]
    }

    /// Out-degree (nnz) of row `r`.
    #[inline]
    pub fn row_nnz(&self, r: usize) -> usize {
        self.row_ptr[r + 1] - self.row_ptr[r]
    }

    /// Check all structural invariants (see module docs).
    pub fn validate(&self) -> Result<()> {
        if self.row_ptr.len() != self.rows + 1 {
            return Err(Error::InvalidSparse(format!(
                "row_ptr len {} != rows+1 {}",
                self.row_ptr.len(),
                self.rows + 1
            )));
        }
        if self.row_ptr[0] != 0 {
            return Err(Error::InvalidSparse("row_ptr[0] != 0".into()));
        }
        if *self.row_ptr.last().unwrap() != self.nnz() {
            return Err(Error::InvalidSparse(format!(
                "row_ptr[rows] {} != nnz {}",
                self.row_ptr.last().unwrap(),
                self.nnz()
            )));
        }
        if self.col_idx.len() != self.values.len() {
            return Err(Error::InvalidSparse("col_idx/values length mismatch".into()));
        }
        for w in self.row_ptr.windows(2) {
            if w[1] < w[0] {
                return Err(Error::InvalidSparse("row_ptr not monotone".into()));
            }
        }
        for r in 0..self.rows {
            let cols = self.row_cols(r);
            for w in cols.windows(2) {
                if w[1] <= w[0] {
                    return Err(Error::InvalidSparse(format!(
                        "row {r}: columns not strictly increasing"
                    )));
                }
            }
            if let Some(&c) = cols.last() {
                if c >= self.cols {
                    return Err(Error::InvalidSparse(format!(
                        "row {r}: col {c} >= cols {}",
                        self.cols
                    )));
                }
            }
        }
        // NaN/Inf values poison every dot product they touch — an
        // untrusted graph with one bad edge weight would otherwise turn
        // into a full matrix of NaN logits (or a downstream panic) instead
        // of a typed error at the trust boundary.
        if let Some(i) = self.values.iter().position(|v| !v.is_finite()) {
            return Err(Error::InvalidSparse(format!(
                "non-finite value {} at nnz index {i}",
                self.values[i]
            )));
        }
        Ok(())
    }

    /// Transpose via a counting pass — O(nnz + rows + cols). The result is a
    /// valid CSR of shape `(cols, rows)`; this is exactly the matrix the
    /// backprop cache stores (paper §3.3).
    pub fn transpose(&self) -> Csr {
        let mut out_ptr = vec![0usize; self.cols + 1];
        for &c in &self.col_idx {
            out_ptr[c + 1] += 1;
        }
        for i in 0..self.cols {
            out_ptr[i + 1] += out_ptr[i];
        }
        let mut cursor = out_ptr.clone();
        let mut out_col = vec![0usize; self.nnz()];
        let mut out_val = vec![0.0f32; self.nnz()];
        for r in 0..self.rows {
            let (s, e) = (self.row_ptr[r], self.row_ptr[r + 1]);
            for i in s..e {
                let c = self.col_idx[i];
                let dst = cursor[c];
                out_col[dst] = r;
                out_val[dst] = self.values[i];
                cursor[c] += 1;
            }
        }
        Csr {
            rows: self.cols,
            cols: self.rows,
            row_ptr: out_ptr,
            col_idx: out_col,
            values: out_val,
        }
    }

    /// Convert to COO triplets.
    pub fn to_coo(&self) -> Coo {
        let mut row_idx = Vec::with_capacity(self.nnz());
        for r in 0..self.rows {
            row_idx.extend(std::iter::repeat(r).take(self.row_nnz(r)));
        }
        Coo {
            rows: self.rows,
            cols: self.cols,
            row_idx,
            col_idx: self.col_idx.clone(),
            values: self.values.clone(),
        }
    }

    /// Convert to CSC (column-compressed); shares the transpose kernel.
    pub fn to_csc(&self) -> Csc {
        let t = self.transpose();
        Csc {
            rows: self.rows,
            cols: self.cols,
            col_ptr: t.row_ptr,
            row_idx: t.col_idx,
            values: t.values,
        }
    }

    /// Materialise as dense — reference/test helper only.
    pub fn to_dense(&self) -> Dense {
        let mut d = Dense::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            for (&c, &v) in self.row_cols(r).iter().zip(self.row_vals(r)) {
                d.set(r, c, v);
            }
        }
        d
    }

    /// Add self-loops: `A + I` (GCN preprocessing). Rows keep sorted order.
    pub fn add_self_loops(&self) -> Result<Csr> {
        if self.rows != self.cols {
            return Err(Error::ShapeMismatch(format!(
                "add_self_loops on non-square {}x{}",
                self.rows, self.cols
            )));
        }
        let mut coo = self.to_coo();
        for i in 0..self.rows {
            coo.push(i, i, 1.0);
        }
        Ok(coo.to_csr())
    }

    /// Scale row `r` values by `s[r]` (left diagonal scaling `D·A`).
    pub fn scale_rows(&self, s: &[f32]) -> Result<Csr> {
        if s.len() != self.rows {
            return Err(Error::ShapeMismatch(format!(
                "scale_rows: {} factors for {} rows",
                s.len(),
                self.rows
            )));
        }
        let mut out = self.clone();
        for r in 0..out.rows {
            let (st, e) = (out.row_ptr[r], out.row_ptr[r + 1]);
            for v in &mut out.values[st..e] {
                *v *= s[r];
            }
        }
        Ok(out)
    }

    /// Scale column `c` values by `s[c]` (right diagonal scaling `A·D`).
    pub fn scale_cols(&self, s: &[f32]) -> Result<Csr> {
        if s.len() != self.cols {
            return Err(Error::ShapeMismatch(format!(
                "scale_cols: {} factors for {} cols",
                s.len(),
                self.cols
            )));
        }
        let mut out = self.clone();
        for (v, &c) in out.values.iter_mut().zip(out.col_idx.iter()) {
            *v *= s[c];
        }
        Ok(out)
    }

    /// Total bytes of the three arrays — used by the cache budget accounting.
    pub fn memory_bytes(&self) -> usize {
        self.row_ptr.len() * std::mem::size_of::<usize>()
            + self.col_idx.len() * std::mem::size_of::<usize>()
            + self.values.len() * std::mem::size_of::<f32>()
    }

    /// Log₂-bucketed row-length histogram: bucket 0 counts empty rows,
    /// bucket `i ≥ 1` counts rows with length in `[2^(i-1), 2^i)`. One
    /// O(rows) pass — cheap enough for the tuner to run per dataset. The
    /// bucket count is `⌈log₂(max_len)⌉ + 2` at most.
    pub fn row_len_histogram(&self) -> Vec<usize> {
        let mut hist = Vec::new();
        for r in 0..self.rows {
            let len = self.row_nnz(r);
            let bucket = if len == 0 { 0 } else { len.ilog2() as usize + 1 };
            if bucket >= hist.len() {
                hist.resize(bucket + 1, 0);
            }
            hist[bucket] += 1;
        }
        hist
    }

    /// Apply a batch of edge insertions/deletions, producing the next
    /// epoch's matrix. The delta is validated at the trust boundary —
    /// mutation deltas arrive from clients on a live serving session, so
    /// every malformed input degrades to a typed [`Error::InvalidSparse`]
    /// instead of a corrupt CSR or a panic deep in a kernel:
    ///
    /// * indices must be in range (`row < rows`, `col < cols`);
    /// * inserted weights must be finite (the same non-finite guard as
    ///   [`Csr::validate`]);
    /// * a single delta may not target the same `(row, col)` twice
    ///   (insert-then-delete within one batch has no defined order);
    /// * deleting an edge that does not exist is an error — a delete is a
    ///   claim about current structure, and silently ignoring a miss would
    ///   let a client's view drift from the server's.
    ///
    /// Inserting over an existing edge replaces its weight (upsert).
    /// Untouched rows are copied wholesale (one `memcpy` per contiguous
    /// run via the slice copies below — no per-element merge), so the cost
    /// is O(nnz) copy + O(touched · row len) merge, and the result is a
    /// valid CSR by construction: within-row merge keeps columns strictly
    /// increasing and the inputs were bounds/finiteness-checked up front.
    pub fn apply_edge_delta(&self, delta: &EdgeDelta) -> Result<Csr> {
        use std::collections::{BTreeMap, HashSet};
        // ops per touched row: col → Some(weight) for upsert, None for
        // delete. Validation happens here, before any building.
        let mut touched: BTreeMap<usize, Vec<(usize, Option<f32>)>> = BTreeMap::new();
        let mut seen: HashSet<(usize, usize)> = HashSet::new();
        let check_bounds = |r: usize, c: usize| -> Result<()> {
            if r >= self.rows || c >= self.cols {
                return Err(Error::InvalidSparse(format!(
                    "delta edge ({r},{c}) out of bounds for {}x{}",
                    self.rows, self.cols
                )));
            }
            Ok(())
        };
        for &(r, c, w) in &delta.insert {
            check_bounds(r, c)?;
            if !w.is_finite() {
                return Err(Error::InvalidSparse(format!(
                    "delta edge ({r},{c}): non-finite weight {w}"
                )));
            }
            if !seen.insert((r, c)) {
                return Err(Error::InvalidSparse(format!(
                    "delta targets edge ({r},{c}) more than once"
                )));
            }
            touched.entry(r).or_default().push((c, Some(w)));
        }
        for &(r, c) in &delta.delete {
            check_bounds(r, c)?;
            if !seen.insert((r, c)) {
                return Err(Error::InvalidSparse(format!(
                    "delta targets edge ({r},{c}) more than once"
                )));
            }
            touched.entry(r).or_default().push((c, None));
        }

        let mut row_ptr = Vec::with_capacity(self.rows + 1);
        row_ptr.push(0usize);
        let cap = self.nnz() + delta.insert.len();
        let mut col_idx = Vec::with_capacity(cap);
        let mut values = Vec::with_capacity(cap);
        for r in 0..self.rows {
            match touched.get_mut(&r) {
                None => {
                    // untouched row: wholesale copy of the old slices
                    col_idx.extend_from_slice(self.row_cols(r));
                    values.extend_from_slice(self.row_vals(r));
                }
                Some(ops) => {
                    ops.sort_unstable_by_key(|&(c, _)| c);
                    let (old_cols, old_vals) = (self.row_cols(r), self.row_vals(r));
                    let (mut i, mut j) = (0usize, 0usize);
                    while i < old_cols.len() || j < ops.len() {
                        let oc = old_cols.get(i).copied();
                        let nc = ops.get(j).map(|&(c, _)| c);
                        match (oc, nc) {
                            (Some(o), Some(n)) if o < n => {
                                col_idx.push(o);
                                values.push(old_vals[i]);
                                i += 1;
                            }
                            (Some(o), Some(n)) if o == n => {
                                // upsert replaces; delete drops
                                if let Some(w) = ops[j].1 {
                                    col_idx.push(n);
                                    values.push(w);
                                }
                                i += 1;
                                j += 1;
                            }
                            (_, Some(n)) => match ops[j].1 {
                                Some(w) => {
                                    col_idx.push(n);
                                    values.push(w);
                                    j += 1;
                                }
                                None => {
                                    return Err(Error::InvalidSparse(format!(
                                        "delta deletes missing edge ({r},{n})"
                                    )));
                                }
                            },
                            (Some(o), None) => {
                                col_idx.push(o);
                                values.push(old_vals[i]);
                                i += 1;
                            }
                            (None, None) => unreachable!("loop condition"),
                        }
                    }
                }
            }
            row_ptr.push(col_idx.len());
        }
        Ok(Csr::from_parts_unchecked(self.rows, self.cols, row_ptr, col_idx, values))
    }

    /// Mean / median / tail row-length statistics (see [`RowLenStats`]).
    /// O(rows log rows); drives the tuner's sparse-format pruning
    /// heuristic and the tuning reports.
    pub fn row_len_stats(&self) -> RowLenStats {
        if self.rows == 0 {
            return RowLenStats { mean: 0.0, p50: 0, p99: 0, max: 0 };
        }
        let mut lens: Vec<usize> = (0..self.rows).map(|r| self.row_nnz(r)).collect();
        lens.sort_unstable();
        let n = lens.len();
        RowLenStats {
            mean: self.nnz() as f64 / n as f64,
            p50: lens[(n - 1) / 2],
            p99: lens[(n - 1) * 99 / 100],
            max: lens[n - 1],
        }
    }
}

/// Row-length summary of a sparse matrix — the shape signal behind the
/// tuner's sparse-format axis. Power-law GNN graphs show a small mean with
/// a heavy tail (`p99 ≫ mean`); that is exactly when sorted/sliced formats
/// (SELL-C-σ, sorted CSR) beat plain CSR.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RowLenStats {
    /// Mean row length (`nnz / rows`).
    pub mean: f64,
    /// Median row length.
    pub p50: usize,
    /// 99th-percentile row length (nearest-rank).
    pub p99: usize,
    /// Longest row.
    pub max: usize,
}

impl RowLenStats {
    /// Tail skew: `p99 / mean` (0 for an empty matrix).
    pub fn skew(&self) -> f64 {
        if self.mean <= 0.0 {
            0.0
        } else {
            self.p99 as f64 / self.mean
        }
    }

    /// Cheap pruning heuristic for the tuner's format axis: sliced/sorted
    /// formats amortise per-row loop overhead (wins on short rows) and
    /// group skewed lengths (wins on heavy tails); on long uniform rows
    /// CSR's streaming inner loop already saturates and the format
    /// candidates would only burn tuning time. Thresholds are deliberately
    /// permissive — the tuner still *measures*, this only prunes the
    /// clearly hopeless case.
    pub fn format_promising(&self) -> bool {
        self.max > 0 && (self.mean <= 32.0 || self.skew() >= 2.0)
    }
}

/// A batch of incremental edge mutations against one adjacency matrix —
/// the input to [`Csr::apply_edge_delta`] and, one level up, to the
/// serving registry's live-mutation path. Built with the fluent helpers
/// (`EdgeDelta::new().add(0, 3, 1.0).del(2, 5)`) or by filling the public
/// fields directly.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct EdgeDelta {
    /// Edges to insert — or re-weight, when the edge already exists
    /// (upsert): `(row, col, weight)`.
    pub insert: Vec<(usize, usize, f32)>,
    /// Edges to remove: `(row, col)`. Removing a missing edge is a
    /// validation error, not a no-op.
    pub delete: Vec<(usize, usize)>,
}

impl EdgeDelta {
    /// An empty delta.
    pub fn new() -> Self {
        EdgeDelta::default()
    }

    /// Queue an edge insert/upsert.
    pub fn add(mut self, row: usize, col: usize, weight: f32) -> Self {
        self.insert.push((row, col, weight));
        self
    }

    /// Queue an edge delete.
    pub fn del(mut self, row: usize, col: usize) -> Self {
        self.delete.push((row, col));
        self
    }

    /// Total queued mutations.
    pub fn len(&self) -> usize {
        self.insert.len() + self.delete.len()
    }

    /// True when no mutations are queued.
    pub fn is_empty(&self) -> bool {
        self.insert.is_empty() && self.delete.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Csr {
        // [[0 1 2]
        //  [0 0 0]
        //  [3 0 4]]
        Csr::from_parts(3, 3, vec![0, 2, 2, 4], vec![1, 2, 0, 2], vec![1.0, 2.0, 3.0, 4.0])
            .unwrap()
    }

    #[test]
    fn validate_catches_bad_ptr() {
        assert!(Csr::from_parts(2, 2, vec![0, 1], vec![0], vec![1.0]).is_err()); // short ptr
        assert!(Csr::from_parts(2, 2, vec![1, 1, 1], vec![0], vec![1.0]).is_err()); // ptr[0]!=0
        assert!(Csr::from_parts(2, 2, vec![0, 2, 1], vec![0, 1], vec![1.0, 1.0]).is_err());
        // non-monotone
    }

    #[test]
    fn validate_catches_bad_cols() {
        // out of range
        assert!(Csr::from_parts(2, 2, vec![0, 1, 1], vec![5], vec![1.0]).is_err());
        // duplicate within row
        assert!(Csr::from_parts(1, 3, vec![0, 2], vec![1, 1], vec![1.0, 1.0]).is_err());
        // unsorted within row
        assert!(Csr::from_parts(1, 3, vec![0, 2], vec![2, 0], vec![1.0, 1.0]).is_err());
    }

    #[test]
    fn validate_catches_non_finite_values() {
        assert!(Csr::from_parts(1, 2, vec![0, 1], vec![0], vec![f32::NAN]).is_err());
        assert!(Csr::from_parts(1, 2, vec![0, 1], vec![0], vec![f32::INFINITY]).is_err());
        assert!(Csr::from_parts(1, 2, vec![0, 1], vec![0], vec![f32::NEG_INFINITY]).is_err());
        // a structurally valid matrix mutated to carry a NaN fails too
        let mut m = sample();
        m.values[2] = f32::NAN;
        let err = m.validate().unwrap_err();
        assert!(err.to_string().contains("non-finite"), "{err}");
    }

    #[test]
    fn row_accessors() {
        let m = sample();
        assert_eq!(m.row_cols(0), &[1, 2]);
        assert_eq!(m.row_vals(2), &[3.0, 4.0]);
        assert_eq!(m.row_nnz(1), 0);
    }

    #[test]
    fn transpose_roundtrip() {
        let m = sample();
        let t = m.transpose();
        t.validate().unwrap();
        assert_eq!(t.rows, 3);
        let tt = t.transpose();
        assert_eq!(tt, m);
        // dense check
        assert!(t.to_dense().allclose(&m.to_dense().transpose(), 0.0));
    }

    #[test]
    fn to_coo_roundtrip() {
        let m = sample();
        assert_eq!(m.to_coo().to_csr(), m);
    }

    #[test]
    fn to_csc_matches_transpose() {
        let m = sample();
        let csc = m.to_csc();
        let t = m.transpose();
        assert_eq!(csc.col_ptr, t.row_ptr);
        assert_eq!(csc.row_idx, t.col_idx);
        assert_eq!(csc.values, t.values);
    }

    #[test]
    fn identity_and_self_loops() {
        let i = Csr::identity(3);
        i.validate().unwrap();
        let m = sample();
        let a = m.add_self_loops().unwrap();
        a.validate().unwrap();
        assert_eq!(a.nnz(), m.nnz() + 2); // (0,0)? no — (0,*) has no diag, (1,1) new, (2,2) exists → +...
        // diag (0,0) new, (1,1) new, (2,2) merges with existing 4.0
        let d = a.to_dense();
        assert_eq!(d.get(0, 0), 1.0);
        assert_eq!(d.get(1, 1), 1.0);
        assert_eq!(d.get(2, 2), 5.0);
    }

    #[test]
    fn scaling() {
        let m = sample();
        let r = m.scale_rows(&[2.0, 3.0, 0.5]).unwrap();
        assert_eq!(r.values, vec![2.0, 4.0, 1.5, 2.0]);
        let c = m.scale_cols(&[10.0, 100.0, 1000.0]).unwrap();
        assert_eq!(c.values, vec![100.0, 2000.0, 30.0, 4000.0]);
        assert!(m.scale_rows(&[1.0]).is_err());
        assert!(m.scale_cols(&[1.0]).is_err());
    }

    #[test]
    fn memory_accounting() {
        let m = sample();
        let bytes = m.memory_bytes();
        // row_ptr: 4 usize, col_idx: 4 usize, values: 4 f32
        assert_eq!(bytes, 4 * 8 + 4 * 8 + 4 * 4);
    }

    #[test]
    fn row_len_histogram_buckets() {
        // sample rows have lengths 2, 0, 2
        let m = sample();
        assert_eq!(m.row_len_histogram(), vec![1, 0, 2]); // 1 empty, 0 of len 1, 2 of len 2..3
        // empty matrix → empty histogram
        assert!(Csr::empty(0, 3).row_len_histogram().is_empty());
        // all-empty rows land in bucket 0
        assert_eq!(Csr::empty(4, 4).row_len_histogram(), vec![4]);
        // a length-8 row lands in bucket 4 ([8, 16))
        let hub = Csr::from_parts(1, 8, vec![0, 8], (0..8).collect(), vec![1.0; 8]).unwrap();
        assert_eq!(hub.row_len_histogram(), vec![0, 0, 0, 0, 1]);
        // histogram totals always cover every row
        assert_eq!(m.row_len_histogram().iter().sum::<usize>(), m.rows);
    }

    #[test]
    fn row_len_stats_and_heuristic() {
        let m = sample();
        let s = m.row_len_stats();
        assert!((s.mean - 4.0 / 3.0).abs() < 1e-9);
        assert_eq!(s.p50, 2);
        assert_eq!(s.p99, 2);
        assert_eq!(s.max, 2);
        assert!(s.skew() > 1.0);
        assert!(s.format_promising()); // short rows

        // empty matrix: all zeros, formats pruned
        let e = Csr::empty(0, 0).row_len_stats();
        assert_eq!(e, RowLenStats { mean: 0.0, p50: 0, p99: 0, max: 0 });
        assert_eq!(e.skew(), 0.0);
        assert!(!e.format_promising());
        assert!(!Csr::empty(5, 5).row_len_stats().format_promising());

        // long uniform rows: formats pruned
        let wide = 100usize;
        let long = Csr::from_parts(
            2,
            wide,
            vec![0, wide, 2 * wide],
            (0..wide).chain(0..wide).collect(),
            vec![1.0; 2 * wide],
        )
        .unwrap();
        let s = long.row_len_stats();
        assert_eq!(s.mean, 100.0);
        assert!(s.skew() < 2.0);
        assert!(!s.format_promising());
    }

    #[test]
    fn edge_delta_inserts_deletes_and_upserts() {
        // sample:
        // [[0 1 2]
        //  [0 0 0]
        //  [3 0 4]]
        let m = sample();
        let delta = EdgeDelta::new()
            .add(1, 1, 9.0) // new edge in an empty row
            .add(0, 0, 5.0) // new edge before existing ones
            .add(2, 2, 7.0) // upsert over the 4.0
            .del(0, 2); // remove the 2.0
        let next = m.apply_edge_delta(&delta).unwrap();
        next.validate().unwrap();
        let d = next.to_dense();
        assert_eq!(d.get(0, 0), 5.0);
        assert_eq!(d.get(0, 1), 1.0);
        assert_eq!(d.get(0, 2), 0.0);
        assert_eq!(d.get(1, 1), 9.0);
        assert_eq!(d.get(2, 0), 3.0);
        assert_eq!(d.get(2, 2), 7.0);
        assert_eq!(next.nnz(), m.nnz() + 2 - 1);
        // the source matrix is untouched (next epoch, not in-place)
        assert_eq!(m, sample());
        // an empty delta reproduces the matrix exactly
        assert_eq!(m.apply_edge_delta(&EdgeDelta::new()).unwrap(), m);
        // untouched rows are copied bit-for-bit
        let only_row0 = m.apply_edge_delta(&EdgeDelta::new().add(0, 0, 1.5)).unwrap();
        assert_eq!(only_row0.row_cols(2), m.row_cols(2));
        assert_eq!(only_row0.row_vals(2), m.row_vals(2));
    }

    #[test]
    fn edge_delta_validates_at_the_trust_boundary() {
        let m = sample();
        // out-of-bounds indices
        let err = m.apply_edge_delta(&EdgeDelta::new().add(3, 0, 1.0)).unwrap_err();
        assert!(err.to_string().contains("out of bounds"), "{err}");
        let err = m.apply_edge_delta(&EdgeDelta::new().del(0, 9)).unwrap_err();
        assert!(err.to_string().contains("out of bounds"), "{err}");
        // non-finite weight
        let err = m.apply_edge_delta(&EdgeDelta::new().add(0, 0, f32::NAN)).unwrap_err();
        assert!(err.to_string().contains("non-finite"), "{err}");
        // duplicate target within one delta (insert+insert and insert+delete)
        let err = m
            .apply_edge_delta(&EdgeDelta::new().add(0, 0, 1.0).add(0, 0, 2.0))
            .unwrap_err();
        assert!(err.to_string().contains("more than once"), "{err}");
        let err =
            m.apply_edge_delta(&EdgeDelta::new().add(0, 1, 1.0).del(0, 1)).unwrap_err();
        assert!(err.to_string().contains("more than once"), "{err}");
        // deleting a missing edge is an error, not a silent no-op
        let err = m.apply_edge_delta(&EdgeDelta::new().del(1, 1)).unwrap_err();
        assert!(err.to_string().contains("missing edge"), "{err}");
        // every failure is the typed InvalidSparse variant
        assert!(matches!(err, Error::InvalidSparse(_)));
        // delta helpers
        let d = EdgeDelta::new().add(0, 1, 1.0).del(2, 0);
        assert_eq!(d.len(), 2);
        assert!(!d.is_empty());
        assert!(EdgeDelta::new().is_empty());
    }

    #[test]
    fn edge_delta_merge_keeps_rows_sorted_under_interleaving() {
        // row 0 holds cols {1, 2}; interleave inserts on both sides and
        // between, out of submission order — the merge must sort
        let m = sample();
        let next = m
            .apply_edge_delta(&EdgeDelta::new().add(0, 0, 0.5).del(0, 1).add(0, 2, 1.5))
            .unwrap();
        next.validate().unwrap();
        assert_eq!(next.row_cols(0), &[0, 2]);
        assert_eq!(next.row_vals(0), &[0.5, 1.5]);
    }
}
