//! CSR (compressed sparse row) — the kernel input format (paper §3.5).
//!
//! All SpMM/SDDMM/FusedMM kernels consume this type. Invariants (checked by
//! [`Csr::validate`], relied on by the `unsafe`-free but bounds-hot kernels):
//!
//! 1. `row_ptr.len() == rows + 1`, `row_ptr[0] == 0`, monotone non-decreasing,
//!    `row_ptr[rows] == nnz`.
//! 2. `col_idx[i] < cols` for all `i`.
//! 3. Column indices are sorted strictly increasing within each row (no
//!    duplicates) — the construction path via [`super::Coo::to_csr`]
//!    guarantees this.

use crate::dense::Dense;
use crate::error::{Error, Result};

use super::{Coo, Csc};

/// Compressed-sparse-row matrix with `f32` values.
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    /// Number of rows.
    pub rows: usize,
    /// Number of columns.
    pub cols: usize,
    /// Row offsets, length `rows + 1`.
    pub row_ptr: Vec<usize>,
    /// Column index per non-zero.
    pub col_idx: Vec<usize>,
    /// Value per non-zero.
    pub values: Vec<f32>,
}

impl Csr {
    /// Build from raw parts, validating every invariant.
    pub fn from_parts(
        rows: usize,
        cols: usize,
        row_ptr: Vec<usize>,
        col_idx: Vec<usize>,
        values: Vec<f32>,
    ) -> Result<Self> {
        let m = Csr { rows, cols, row_ptr, col_idx, values };
        m.validate()?;
        Ok(m)
    }

    /// Build from raw parts without validation — for internal construction
    /// paths that guarantee the invariants (e.g. [`Coo::to_csr`]).
    pub(crate) fn from_parts_unchecked(
        rows: usize,
        cols: usize,
        row_ptr: Vec<usize>,
        col_idx: Vec<usize>,
        values: Vec<f32>,
    ) -> Self {
        Csr { rows, cols, row_ptr, col_idx, values }
    }

    /// An identity-free empty matrix.
    pub fn empty(rows: usize, cols: usize) -> Self {
        Csr { rows, cols, row_ptr: vec![0; rows + 1], col_idx: Vec::new(), values: Vec::new() }
    }

    /// Identity matrix (used for self-loop insertion tests).
    pub fn identity(n: usize) -> Self {
        Csr {
            rows: n,
            cols: n,
            row_ptr: (0..=n).collect(),
            col_idx: (0..n).collect(),
            values: vec![1.0; n],
        }
    }

    /// Number of non-zeros.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Column indices of row `r`.
    #[inline]
    pub fn row_cols(&self, r: usize) -> &[usize] {
        &self.col_idx[self.row_ptr[r]..self.row_ptr[r + 1]]
    }

    /// Values of row `r`.
    #[inline]
    pub fn row_vals(&self, r: usize) -> &[f32] {
        &self.values[self.row_ptr[r]..self.row_ptr[r + 1]]
    }

    /// Out-degree (nnz) of row `r`.
    #[inline]
    pub fn row_nnz(&self, r: usize) -> usize {
        self.row_ptr[r + 1] - self.row_ptr[r]
    }

    /// Check all structural invariants (see module docs).
    pub fn validate(&self) -> Result<()> {
        if self.row_ptr.len() != self.rows + 1 {
            return Err(Error::InvalidSparse(format!(
                "row_ptr len {} != rows+1 {}",
                self.row_ptr.len(),
                self.rows + 1
            )));
        }
        if self.row_ptr[0] != 0 {
            return Err(Error::InvalidSparse("row_ptr[0] != 0".into()));
        }
        if *self.row_ptr.last().unwrap() != self.nnz() {
            return Err(Error::InvalidSparse(format!(
                "row_ptr[rows] {} != nnz {}",
                self.row_ptr.last().unwrap(),
                self.nnz()
            )));
        }
        if self.col_idx.len() != self.values.len() {
            return Err(Error::InvalidSparse("col_idx/values length mismatch".into()));
        }
        for w in self.row_ptr.windows(2) {
            if w[1] < w[0] {
                return Err(Error::InvalidSparse("row_ptr not monotone".into()));
            }
        }
        for r in 0..self.rows {
            let cols = self.row_cols(r);
            for w in cols.windows(2) {
                if w[1] <= w[0] {
                    return Err(Error::InvalidSparse(format!(
                        "row {r}: columns not strictly increasing"
                    )));
                }
            }
            if let Some(&c) = cols.last() {
                if c >= self.cols {
                    return Err(Error::InvalidSparse(format!(
                        "row {r}: col {c} >= cols {}",
                        self.cols
                    )));
                }
            }
        }
        Ok(())
    }

    /// Transpose via a counting pass — O(nnz + rows + cols). The result is a
    /// valid CSR of shape `(cols, rows)`; this is exactly the matrix the
    /// backprop cache stores (paper §3.3).
    pub fn transpose(&self) -> Csr {
        let mut out_ptr = vec![0usize; self.cols + 1];
        for &c in &self.col_idx {
            out_ptr[c + 1] += 1;
        }
        for i in 0..self.cols {
            out_ptr[i + 1] += out_ptr[i];
        }
        let mut cursor = out_ptr.clone();
        let mut out_col = vec![0usize; self.nnz()];
        let mut out_val = vec![0.0f32; self.nnz()];
        for r in 0..self.rows {
            let (s, e) = (self.row_ptr[r], self.row_ptr[r + 1]);
            for i in s..e {
                let c = self.col_idx[i];
                let dst = cursor[c];
                out_col[dst] = r;
                out_val[dst] = self.values[i];
                cursor[c] += 1;
            }
        }
        Csr {
            rows: self.cols,
            cols: self.rows,
            row_ptr: out_ptr,
            col_idx: out_col,
            values: out_val,
        }
    }

    /// Convert to COO triplets.
    pub fn to_coo(&self) -> Coo {
        let mut row_idx = Vec::with_capacity(self.nnz());
        for r in 0..self.rows {
            row_idx.extend(std::iter::repeat(r).take(self.row_nnz(r)));
        }
        Coo {
            rows: self.rows,
            cols: self.cols,
            row_idx,
            col_idx: self.col_idx.clone(),
            values: self.values.clone(),
        }
    }

    /// Convert to CSC (column-compressed); shares the transpose kernel.
    pub fn to_csc(&self) -> Csc {
        let t = self.transpose();
        Csc {
            rows: self.rows,
            cols: self.cols,
            col_ptr: t.row_ptr,
            row_idx: t.col_idx,
            values: t.values,
        }
    }

    /// Materialise as dense — reference/test helper only.
    pub fn to_dense(&self) -> Dense {
        let mut d = Dense::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            for (&c, &v) in self.row_cols(r).iter().zip(self.row_vals(r)) {
                d.set(r, c, v);
            }
        }
        d
    }

    /// Add self-loops: `A + I` (GCN preprocessing). Rows keep sorted order.
    pub fn add_self_loops(&self) -> Result<Csr> {
        if self.rows != self.cols {
            return Err(Error::ShapeMismatch(format!(
                "add_self_loops on non-square {}x{}",
                self.rows, self.cols
            )));
        }
        let mut coo = self.to_coo();
        for i in 0..self.rows {
            coo.push(i, i, 1.0);
        }
        Ok(coo.to_csr())
    }

    /// Scale row `r` values by `s[r]` (left diagonal scaling `D·A`).
    pub fn scale_rows(&self, s: &[f32]) -> Result<Csr> {
        if s.len() != self.rows {
            return Err(Error::ShapeMismatch(format!(
                "scale_rows: {} factors for {} rows",
                s.len(),
                self.rows
            )));
        }
        let mut out = self.clone();
        for r in 0..out.rows {
            let (st, e) = (out.row_ptr[r], out.row_ptr[r + 1]);
            for v in &mut out.values[st..e] {
                *v *= s[r];
            }
        }
        Ok(out)
    }

    /// Scale column `c` values by `s[c]` (right diagonal scaling `A·D`).
    pub fn scale_cols(&self, s: &[f32]) -> Result<Csr> {
        if s.len() != self.cols {
            return Err(Error::ShapeMismatch(format!(
                "scale_cols: {} factors for {} cols",
                s.len(),
                self.cols
            )));
        }
        let mut out = self.clone();
        for (v, &c) in out.values.iter_mut().zip(out.col_idx.iter()) {
            *v *= s[c];
        }
        Ok(out)
    }

    /// Total bytes of the three arrays — used by the cache budget accounting.
    pub fn memory_bytes(&self) -> usize {
        self.row_ptr.len() * std::mem::size_of::<usize>()
            + self.col_idx.len() * std::mem::size_of::<usize>()
            + self.values.len() * std::mem::size_of::<f32>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Csr {
        // [[0 1 2]
        //  [0 0 0]
        //  [3 0 4]]
        Csr::from_parts(3, 3, vec![0, 2, 2, 4], vec![1, 2, 0, 2], vec![1.0, 2.0, 3.0, 4.0])
            .unwrap()
    }

    #[test]
    fn validate_catches_bad_ptr() {
        assert!(Csr::from_parts(2, 2, vec![0, 1], vec![0], vec![1.0]).is_err()); // short ptr
        assert!(Csr::from_parts(2, 2, vec![1, 1, 1], vec![0], vec![1.0]).is_err()); // ptr[0]!=0
        assert!(Csr::from_parts(2, 2, vec![0, 2, 1], vec![0, 1], vec![1.0, 1.0]).is_err());
        // non-monotone
    }

    #[test]
    fn validate_catches_bad_cols() {
        // out of range
        assert!(Csr::from_parts(2, 2, vec![0, 1, 1], vec![5], vec![1.0]).is_err());
        // duplicate within row
        assert!(Csr::from_parts(1, 3, vec![0, 2], vec![1, 1], vec![1.0, 1.0]).is_err());
        // unsorted within row
        assert!(Csr::from_parts(1, 3, vec![0, 2], vec![2, 0], vec![1.0, 1.0]).is_err());
    }

    #[test]
    fn row_accessors() {
        let m = sample();
        assert_eq!(m.row_cols(0), &[1, 2]);
        assert_eq!(m.row_vals(2), &[3.0, 4.0]);
        assert_eq!(m.row_nnz(1), 0);
    }

    #[test]
    fn transpose_roundtrip() {
        let m = sample();
        let t = m.transpose();
        t.validate().unwrap();
        assert_eq!(t.rows, 3);
        let tt = t.transpose();
        assert_eq!(tt, m);
        // dense check
        assert!(t.to_dense().allclose(&m.to_dense().transpose(), 0.0));
    }

    #[test]
    fn to_coo_roundtrip() {
        let m = sample();
        assert_eq!(m.to_coo().to_csr(), m);
    }

    #[test]
    fn to_csc_matches_transpose() {
        let m = sample();
        let csc = m.to_csc();
        let t = m.transpose();
        assert_eq!(csc.col_ptr, t.row_ptr);
        assert_eq!(csc.row_idx, t.col_idx);
        assert_eq!(csc.values, t.values);
    }

    #[test]
    fn identity_and_self_loops() {
        let i = Csr::identity(3);
        i.validate().unwrap();
        let m = sample();
        let a = m.add_self_loops().unwrap();
        a.validate().unwrap();
        assert_eq!(a.nnz(), m.nnz() + 2); // (0,0)? no — (0,*) has no diag, (1,1) new, (2,2) exists → +...
        // diag (0,0) new, (1,1) new, (2,2) merges with existing 4.0
        let d = a.to_dense();
        assert_eq!(d.get(0, 0), 1.0);
        assert_eq!(d.get(1, 1), 1.0);
        assert_eq!(d.get(2, 2), 5.0);
    }

    #[test]
    fn scaling() {
        let m = sample();
        let r = m.scale_rows(&[2.0, 3.0, 0.5]).unwrap();
        assert_eq!(r.values, vec![2.0, 4.0, 1.5, 2.0]);
        let c = m.scale_cols(&[10.0, 100.0, 1000.0]).unwrap();
        assert_eq!(c.values, vec![100.0, 2000.0, 30.0, 4000.0]);
        assert!(m.scale_rows(&[1.0]).is_err());
        assert!(m.scale_cols(&[1.0]).is_err());
    }

    #[test]
    fn memory_accounting() {
        let m = sample();
        let bytes = m.memory_bytes();
        // row_ptr: 4 usize, col_idx: 4 usize, values: 4 f32
        assert_eq!(bytes, 4 * 8 + 4 * 8 + 4 * 4);
    }
}
