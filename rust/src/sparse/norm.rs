//! Graph normalisations used by the GNN layers.
//!
//! GCN uses the symmetric normalisation `D^{-1/2} (A + I) D^{-1/2}` (Kipf &
//! Welling); GraphSAGE-mean uses the row-stochastic `D^{-1} A`. Both are
//! *preprocessing* in iSpLib: they're computed once, cached (paper §3.3),
//! and the per-epoch hot path only runs SpMM against the cached matrix.

use crate::error::{Error, Result};

use super::Csr;

/// Which normalisation to apply to an adjacency before training.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum NormKind {
    /// No normalisation (GIN, GraphSAGE-sum use the raw adjacency).
    None,
    /// Symmetric GCN normalisation with self-loops:
    /// `D^{-1/2} (A+I) D^{-1/2}`.
    GcnSym,
    /// Row-stochastic `D^{-1} A` (GraphSAGE-mean).
    RowMean,
}

impl NormKind {
    /// Parse from the CLI / config string form.
    pub fn parse(s: &str) -> Result<NormKind> {
        match s {
            "none" => Ok(NormKind::None),
            "gcn" | "sym" => Ok(NormKind::GcnSym),
            "mean" | "row" => Ok(NormKind::RowMean),
            other => Err(Error::UnknownName(format!("norm kind '{other}'"))),
        }
    }

    /// Apply this normalisation to `a`.
    pub fn apply(self, a: &Csr) -> Result<Csr> {
        match self {
            NormKind::None => Ok(a.clone()),
            NormKind::GcnSym => gcn_normalize(a),
            NormKind::RowMean => row_normalize(a),
        }
    }
}

/// Weighted out-degree vector: `deg[r] = Σ_c A[r,c]`.
pub fn degree_vector(a: &Csr) -> Vec<f32> {
    (0..a.rows).map(|r| a.row_vals(r).iter().sum()).collect()
}

/// Count-based out-degree (number of neighbours, ignores weights). This is
/// the denominator for the `mean` semiring reduction.
pub fn degree_counts(a: &Csr) -> Vec<f32> {
    (0..a.rows).map(|r| a.row_nnz(r) as f32).collect()
}

/// Symmetric GCN normalisation with self-loops:
/// `Â = D̂^{-1/2} (A + I) D̂^{-1/2}` where `D̂` is the degree of `A + I`.
pub fn gcn_normalize(a: &Csr) -> Result<Csr> {
    let a_hat = a.add_self_loops()?;
    let deg = degree_vector(&a_hat);
    let inv_sqrt: Vec<f32> =
        deg.iter().map(|&d| if d > 0.0 { 1.0 / d.sqrt() } else { 0.0 }).collect();
    a_hat.scale_rows(&inv_sqrt)?.scale_cols(&inv_sqrt)
}

/// Row-stochastic normalisation `D^{-1} A`; zero-degree rows stay zero.
pub fn row_normalize(a: &Csr) -> Result<Csr> {
    let deg = degree_vector(a);
    let inv: Vec<f32> = deg.iter().map(|&d| if d > 0.0 { 1.0 / d } else { 0.0 }).collect();
    a.scale_rows(&inv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Coo;

    fn path_graph(n: usize) -> Csr {
        // 0 - 1 - 2 - ... - (n-1), undirected, unweighted
        let mut coo = Coo::new(n, n);
        for i in 0..n - 1 {
            coo.push_sym(i, i + 1, 1.0);
        }
        coo.to_csr()
    }

    #[test]
    fn degree_vectors() {
        let g = path_graph(4);
        assert_eq!(degree_vector(&g), vec![1.0, 2.0, 2.0, 1.0]);
        assert_eq!(degree_counts(&g), vec![1.0, 2.0, 2.0, 1.0]);
    }

    #[test]
    fn row_normalize_rows_sum_to_one() {
        let g = path_graph(5);
        let n = row_normalize(&g).unwrap();
        for r in 0..5 {
            let s: f32 = n.row_vals(r).iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn row_normalize_zero_degree_row_stays_zero() {
        // node 2 is isolated
        let mut coo = Coo::new(3, 3);
        coo.push(0, 1, 1.0);
        coo.push(1, 0, 1.0);
        let g = coo.to_csr();
        let n = row_normalize(&g).unwrap();
        assert_eq!(n.row_nnz(2), 0);
    }

    #[test]
    fn gcn_normalize_symmetric_and_bounded() {
        let g = path_graph(4);
        let n = gcn_normalize(&g).unwrap();
        n.validate().unwrap();
        // Â must be symmetric for undirected A
        let d = n.to_dense();
        let dt = n.transpose().to_dense();
        assert!(d.allclose(&dt, 1e-6));
        // Largest eigval of the GCN-normalised adjacency is 1; all entries in (0,1]
        for &v in &n.values {
            assert!(v > 0.0 && v <= 1.0);
        }
        // diagonal entry of node with degree d is 1/(d+1)
        assert!((d.get(0, 0) - 1.0 / 2.0).abs() < 1e-6);
        assert!((d.get(1, 1) - 1.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn norm_kind_parse_and_apply() {
        assert_eq!(NormKind::parse("gcn").unwrap(), NormKind::GcnSym);
        assert_eq!(NormKind::parse("mean").unwrap(), NormKind::RowMean);
        assert_eq!(NormKind::parse("none").unwrap(), NormKind::None);
        assert!(NormKind::parse("bogus").is_err());
        let g = path_graph(3);
        assert_eq!(NormKind::None.apply(&g).unwrap(), g);
        assert_eq!(NormKind::RowMean.apply(&g).unwrap(), row_normalize(&g).unwrap());
    }
}
