//! SELL-C-σ and row-length-sorted CSR — the tuner's sparse-format axis.
//!
//! CSR's row-oriented inner loop degrades on power-law GNN graphs: most
//! rows are short (the loop over a 2-entry row is all overhead) while a
//! few hub rows are enormous. Qiu et al. show the matrix *representation*
//! — not just the kernel implementation — is the dominant SpMM lever on
//! such graphs. This module provides the two representations the
//! auto-tuner can now choose:
//!
//! * [`Sell`] — **SELL-C-σ** (sliced ELL with sorting): rows are sorted by
//!   descending length inside windows of σ consecutive rows, then packed
//!   into slices of C rows each. A slice stores its entries column-major,
//!   padded to the slice's longest row, so the kernel walks `C` rows in
//!   lockstep with a branch-free lane loop — short skewed rows amortise
//!   loop overhead across the slice instead of paying it per row. σ bounds
//!   how far a row may move from its home position, keeping the output
//!   permutation *local* (a property the parallel kernel exploits: σ-window
//!   boundaries are also valid contiguous output-partition boundaries).
//! * [`SortedCsr`] — plain CSR with rows globally sorted by descending
//!   length (the σ → ∞ limit). No padding, perfect NNZ balance at the top
//!   of the matrix where the hubs cluster, at the cost of a global output
//!   permutation.
//!
//! ## The inverse-permutation equality argument
//!
//! Both formats are **pure row permutations with unchanged within-row entry
//! order**: position `p` of the permuted layout holds exactly the entries
//! of original row `perm[p]`, in the same column-sorted order CSR stores
//! them. An SpMM kernel over either format therefore combines each output
//! element's neighbour stream in *exactly* the trusted CSR kernel's order —
//! only the traversal order **across** rows (and the memory layout) change
//! — and scatters each finished row back through `perm`. Padding entries
//! are never read (the kernels track per-lane lengths), so they cannot
//! perturb any semiring. The result is **bitwise identical** to the
//! trusted kernel for every semiring, which is what lets the tuner pick a
//! format as freely as it picks a kernel implementation (asserted by the
//! kernel proptests).
//!
//! Conversions are O(nnz) and cached per graph in the
//! [`KernelWorkspace`](crate::kernels::KernelWorkspace), so training and
//! serving pay them once per graph, never per call.

use std::cmp::Reverse;

use super::Csr;

/// SELL-C-σ matrix. See the module docs for the layout; invariants:
///
/// 1. `perm` is a permutation of `0..rows` in which every index stays
///    inside its σ-window: `perm[p] / sigma == p / sigma`.
/// 2. `sigma` is a positive multiple of `c` (the constructor rounds the
///    requested window up), so slices never straddle windows and `lens`
///    is non-increasing within every slice.
/// 3. Slice `s` holds `lanes = min(c, rows - s*c)` rows; its storage is
///    `width * lanes` entries at `slice_ptr[s]`, column-major: entry `j`
///    of lane `i` lives at `slice_ptr[s] + j*lanes + i`. Entries past a
///    lane's `lens` are padding (col 0, value 0.0) and are never read.
#[derive(Clone, Debug, PartialEq)]
pub struct Sell {
    /// Number of rows (of the original matrix).
    pub rows: usize,
    /// Number of columns.
    pub cols: usize,
    /// Slice height C.
    pub c: usize,
    /// Effective sort-window σ: a positive multiple of `c`.
    pub sigma: usize,
    /// Stored non-zeros (excluding padding).
    nnz: usize,
    /// `perm[p]` = original row held at permuted position `p`.
    pub perm: Vec<usize>,
    /// Row length (nnz) per permuted position.
    pub lens: Vec<usize>,
    /// Per-slice start offset into `col_idx`/`values`, length
    /// `n_slices + 1`.
    pub slice_ptr: Vec<usize>,
    /// Column index per slot (padding slots hold 0).
    pub col_idx: Vec<usize>,
    /// Value per slot (padding slots hold 0.0).
    pub values: Vec<f32>,
    /// Stored non-zeros per σ-window (for window-granular partitioning).
    pub window_nnz: Vec<usize>,
}

impl Sell {
    /// The window the constructor actually sorts with: the requested σ
    /// rounded up to a positive multiple of `c`. This is what keeps
    /// slices from straddling windows (invariant 2).
    pub fn effective_sigma(c: usize, sigma: usize) -> usize {
        let c = c.max(1);
        sigma.max(1).div_ceil(c) * c
    }

    /// Convert from CSR. `c` and `sigma` are clamped to ≥ 1 and σ is
    /// rounded up to a multiple of C (see [`Sell::effective_sigma`]).
    pub fn from_csr(a: &Csr, c: usize, sigma: usize) -> Sell {
        let c = c.max(1);
        let sigma = Self::effective_sigma(c, sigma);
        let rows = a.rows;

        // σ-window sort: stable descending by row length, so equal-length
        // rows keep their original order (deterministic layout).
        let mut perm: Vec<usize> = (0..rows).collect();
        let mut window_nnz = Vec::with_capacity(rows.div_ceil(sigma.max(1)));
        let mut w0 = 0;
        while w0 < rows {
            let w1 = (w0 + sigma).min(rows);
            perm[w0..w1].sort_by_key(|&r| Reverse(a.row_nnz(r)));
            window_nnz.push(perm[w0..w1].iter().map(|&r| a.row_nnz(r)).sum());
            w0 = w1;
        }
        let lens: Vec<usize> = perm.iter().map(|&r| a.row_nnz(r)).collect();

        // slice extents: each slice is padded to its longest lane
        let n_slices = rows.div_ceil(c);
        let mut slice_ptr = Vec::with_capacity(n_slices + 1);
        slice_ptr.push(0usize);
        for s in 0..n_slices {
            let base = s * c;
            let lanes = c.min(rows - base);
            let width = lens[base..base + lanes].iter().copied().max().unwrap_or(0);
            slice_ptr.push(slice_ptr[s] + width * lanes);
        }

        // column-major fill; padding slots keep (0, 0.0) and are never read
        let padded = *slice_ptr.last().unwrap();
        let mut col_idx = vec![0usize; padded];
        let mut values = vec![0.0f32; padded];
        for s in 0..n_slices {
            let base = s * c;
            let lanes = c.min(rows - base);
            let off = slice_ptr[s];
            for i in 0..lanes {
                let orig = perm[base + i];
                for (j, (&cc, &v)) in a.row_cols(orig).iter().zip(a.row_vals(orig)).enumerate() {
                    col_idx[off + j * lanes + i] = cc;
                    values[off + j * lanes + i] = v;
                }
            }
        }

        Sell {
            rows,
            cols: a.cols,
            c,
            sigma,
            nnz: a.nnz(),
            perm,
            lens,
            slice_ptr,
            col_idx,
            values,
            window_nnz,
        }
    }

    /// Stored non-zeros (excluding padding).
    #[inline]
    pub fn nnz(&self) -> usize {
        self.nnz
    }

    /// Number of C-row slices.
    #[inline]
    pub fn n_slices(&self) -> usize {
        self.slice_ptr.len() - 1
    }

    /// Rows held by slice `s` (the last slice may be partial).
    #[inline]
    pub fn slice_lanes(&self, s: usize) -> usize {
        self.c.min(self.rows - s * self.c)
    }

    /// Padded width (longest lane) of slice `s`.
    #[inline]
    pub fn slice_width(&self, s: usize) -> usize {
        let lanes = self.slice_lanes(s);
        if lanes == 0 {
            0
        } else {
            (self.slice_ptr[s + 1] - self.slice_ptr[s]) / lanes
        }
    }

    /// Total slots including padding.
    pub fn padded_nnz(&self) -> usize {
        self.values.len()
    }

    /// `padded / stored` — 1.0 means zero padding waste. The tuning report
    /// surfaces this so a bad (C, σ) choice is visible.
    pub fn padding_ratio(&self) -> f64 {
        if self.nnz == 0 {
            1.0
        } else {
            self.padded_nnz() as f64 / self.nnz as f64
        }
    }

    /// Exact inverse conversion: rebuilds the original CSR (bit-for-bit —
    /// the permutation is inverted and within-row entry order was never
    /// changed).
    pub fn to_csr(&self) -> Csr {
        let mut row_ptr = vec![0usize; self.rows + 1];
        for (p, &orig) in self.perm.iter().enumerate() {
            row_ptr[orig + 1] = self.lens[p];
        }
        for i in 0..self.rows {
            row_ptr[i + 1] += row_ptr[i];
        }
        let mut col_idx = vec![0usize; self.nnz];
        let mut values = vec![0.0f32; self.nnz];
        for s in 0..self.n_slices() {
            let base = s * self.c;
            let lanes = self.slice_lanes(s);
            let off = self.slice_ptr[s];
            for i in 0..lanes {
                let p = base + i;
                let dst = row_ptr[self.perm[p]];
                for j in 0..self.lens[p] {
                    col_idx[dst + j] = self.col_idx[off + j * lanes + i];
                    values[dst + j] = self.values[off + j * lanes + i];
                }
            }
        }
        Csr::from_parts_unchecked(self.rows, self.cols, row_ptr, col_idx, values)
    }

    /// Check the structural invariants (module docs) — test/debug helper.
    pub fn validate(&self) -> crate::error::Result<()> {
        use crate::error::Error;
        if self.sigma == 0 || self.c == 0 || self.sigma % self.c != 0 {
            return Err(Error::InvalidSparse(format!(
                "sell: sigma {} not a positive multiple of c {}",
                self.sigma, self.c
            )));
        }
        if self.perm.len() != self.rows || self.lens.len() != self.rows {
            return Err(Error::InvalidSparse("sell: perm/lens length mismatch".into()));
        }
        let mut seen = vec![false; self.rows];
        for (p, &orig) in self.perm.iter().enumerate() {
            if orig >= self.rows || seen[orig] {
                return Err(Error::InvalidSparse(format!("sell: bad permutation at {p}")));
            }
            if orig / self.sigma != p / self.sigma {
                return Err(Error::InvalidSparse(format!(
                    "sell: row {orig} escaped its σ-window (position {p})"
                )));
            }
            seen[orig] = true;
        }
        for s in 0..self.n_slices() {
            let base = s * self.c;
            let lanes = self.slice_lanes(s);
            let width = self.slice_width(s);
            for i in 0..lanes {
                if self.lens[base + i] > width {
                    return Err(Error::InvalidSparse(format!("sell: lane overflows slice {s}")));
                }
                if i > 0 && self.lens[base + i] > self.lens[base + i - 1] {
                    return Err(Error::InvalidSparse(format!(
                        "sell: lens not non-increasing within slice {s}"
                    )));
                }
            }
        }
        if self.lens.iter().sum::<usize>() != self.nnz {
            return Err(Error::InvalidSparse("sell: lens don't sum to nnz".into()));
        }
        Ok(())
    }

    /// Total bytes of the arrays — cache-budget accounting, mirroring
    /// [`Csr::memory_bytes`].
    pub fn memory_bytes(&self) -> usize {
        let us = std::mem::size_of::<usize>();
        (self.perm.len() + self.lens.len() + self.slice_ptr.len() + self.col_idx.len()) * us
            + self.values.len() * std::mem::size_of::<f32>()
            + self.window_nnz.len() * us
    }
}

/// CSR with rows stably sorted by descending length — the σ → ∞ limit of
/// SELL-C-σ. `csr` row `p` holds original row `perm[p]` verbatim (same
/// within-row entry order), so SpMM over it is bitwise-equal to trusted
/// after scattering rows back through `perm`.
#[derive(Clone, Debug, PartialEq)]
pub struct SortedCsr {
    /// The permuted matrix (row `p` = original row `perm[p]`).
    pub csr: Csr,
    /// `perm[p]` = original row held at permuted position `p`.
    pub perm: Vec<usize>,
}

impl SortedCsr {
    /// Convert from CSR: stable descending row-length sort.
    pub fn from_csr(a: &Csr) -> SortedCsr {
        let mut perm: Vec<usize> = (0..a.rows).collect();
        perm.sort_by_key(|&r| Reverse(a.row_nnz(r)));
        let mut row_ptr = Vec::with_capacity(a.rows + 1);
        row_ptr.push(0usize);
        let mut col_idx = Vec::with_capacity(a.nnz());
        let mut values = Vec::with_capacity(a.nnz());
        for &orig in &perm {
            col_idx.extend_from_slice(a.row_cols(orig));
            values.extend_from_slice(a.row_vals(orig));
            row_ptr.push(col_idx.len());
        }
        SortedCsr {
            csr: Csr::from_parts_unchecked(a.rows, a.cols, row_ptr, col_idx, values),
            perm,
        }
    }

    /// Number of rows.
    #[inline]
    pub fn rows(&self) -> usize {
        self.csr.rows
    }

    /// Number of columns.
    #[inline]
    pub fn cols(&self) -> usize {
        self.csr.cols
    }

    /// Stored non-zeros.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.csr.nnz()
    }

    /// Exact inverse conversion back to the original row order.
    pub fn to_csr(&self) -> Csr {
        let rows = self.csr.rows;
        let mut row_ptr = vec![0usize; rows + 1];
        for (p, &orig) in self.perm.iter().enumerate() {
            row_ptr[orig + 1] = self.csr.row_nnz(p);
        }
        for i in 0..rows {
            row_ptr[i + 1] += row_ptr[i];
        }
        let mut col_idx = vec![0usize; self.nnz()];
        let mut values = vec![0.0f32; self.nnz()];
        for (p, &orig) in self.perm.iter().enumerate() {
            let dst = row_ptr[orig];
            let n = self.csr.row_nnz(p);
            col_idx[dst..dst + n].copy_from_slice(self.csr.row_cols(p));
            values[dst..dst + n].copy_from_slice(self.csr.row_vals(p));
        }
        Csr::from_parts_unchecked(rows, self.csr.cols, row_ptr, col_idx, values)
    }

    /// Total bytes — cache-budget accounting.
    pub fn memory_bytes(&self) -> usize {
        self.csr.memory_bytes() + self.perm.len() * std::mem::size_of::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Coo;
    use crate::util::rng::Rng;

    fn skewed(n: usize, seed: u64) -> Csr {
        // a few hubs, many short rows, some empty rows
        let mut rng = Rng::seed_from_u64(seed);
        let mut coo = Coo::new(n, n);
        for r in 0..n {
            let deg = if r % 17 == 0 {
                12
            } else if r % 3 == 0 {
                0
            } else {
                1 + rng.gen_range(3)
            };
            for _ in 0..deg {
                coo.push(r, rng.gen_range(n), rng.gen_range_f32(0.1, 1.0));
            }
        }
        coo.to_csr()
    }

    #[test]
    fn sell_roundtrip_exact() {
        let a = skewed(50, 1);
        for (c, sigma) in [(1, 1), (4, 4), (4, 32), (8, 8), (8, 64), (3, 7), (16, 1000)] {
            let s = Sell::from_csr(&a, c, sigma);
            s.validate().unwrap();
            assert_eq!(s.to_csr(), a, "c={c} sigma={sigma}");
            assert_eq!(s.nnz(), a.nnz());
            assert!(s.padding_ratio() >= 1.0);
        }
    }

    #[test]
    fn sell_sigma_rounds_to_multiple_of_c() {
        assert_eq!(Sell::effective_sigma(4, 4), 4);
        assert_eq!(Sell::effective_sigma(4, 5), 8);
        assert_eq!(Sell::effective_sigma(4, 32), 32);
        assert_eq!(Sell::effective_sigma(8, 1), 8);
        // degenerate params clamp instead of panicking
        assert_eq!(Sell::effective_sigma(0, 0), 1);
        let a = skewed(20, 2);
        let s = Sell::from_csr(&a, 0, 0);
        s.validate().unwrap();
        assert_eq!(s.to_csr(), a);
    }

    #[test]
    fn sell_sorting_reduces_padding() {
        let a = skewed(64, 3);
        // σ = C leaves every slice holding its original 4 rows (sorting a
        // window of exactly one slice cannot change that slice's max). A
        // larger σ sorts across slices, and descending order minimises the
        // sum of per-slice maxima over a window — so padding can only
        // shrink or stay.
        let tight = Sell::from_csr(&a, 4, 64);
        let unsorted_bound = Sell::from_csr(&a, 4, 4);
        assert!(tight.padded_nnz() <= unsorted_bound.padded_nnz());
        // within every slice, lens are non-increasing (the kernel's
        // branch-free active-lane trick depends on this)
        tight.validate().unwrap();
    }

    #[test]
    fn sell_degenerate_shapes() {
        let empty = Csr::empty(0, 5);
        let s = Sell::from_csr(&empty, 4, 32);
        s.validate().unwrap();
        assert_eq!(s.n_slices(), 0);
        assert_eq!(s.to_csr(), empty);

        // all-empty rows → all-empty slices with zero storage
        let zeros = Csr::empty(10, 10);
        let s = Sell::from_csr(&zeros, 4, 8);
        s.validate().unwrap();
        assert_eq!(s.padded_nnz(), 0);
        assert_eq!(s.to_csr(), zeros);
        assert_eq!(s.padding_ratio(), 1.0);
    }

    #[test]
    fn sell_window_nnz_accounts_everything() {
        let a = skewed(40, 4);
        let s = Sell::from_csr(&a, 4, 8);
        assert_eq!(s.window_nnz.iter().sum::<usize>(), a.nnz());
        assert_eq!(s.window_nnz.len(), a.rows.div_ceil(s.sigma));
    }

    #[test]
    fn sorted_csr_roundtrip_and_order() {
        let a = skewed(50, 5);
        let sc = SortedCsr::from_csr(&a);
        sc.csr.validate().unwrap();
        assert_eq!(sc.to_csr(), a);
        assert_eq!(sc.nnz(), a.nnz());
        // rows are in non-increasing length order
        for p in 1..sc.rows() {
            assert!(sc.csr.row_nnz(p) <= sc.csr.row_nnz(p - 1));
        }
        // stable: equal-length rows keep original relative order
        let mut last_seen = vec![];
        for p in 0..sc.rows() {
            last_seen.push((sc.csr.row_nnz(p), sc.perm[p]));
        }
        for w in last_seen.windows(2) {
            if w[0].0 == w[1].0 {
                assert!(w[0].1 < w[1].1, "stable sort violated");
            }
        }
    }

    #[test]
    fn memory_accounting_positive() {
        let a = skewed(30, 6);
        assert!(Sell::from_csr(&a, 4, 16).memory_bytes() > 0);
        assert!(SortedCsr::from_csr(&a).memory_bytes() > a.memory_bytes());
    }
}
