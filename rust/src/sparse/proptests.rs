//! Property tests for the sparse substrate itself (format invariants,
//! normalisation identities). Kernel-level properties live in
//! `kernels::proptests`.

use super::{degree_vector, gcn_normalize, row_normalize, Coo, Csr};
use crate::util::check::forall;
use crate::util::rng::Rng;

/// Random undirected simple graph over `n` nodes.
fn arb_sym_graph(rng: &mut Rng, n: usize) -> Csr {
    let n_edges = rng.gen_range(n * 3 + 1);
    let mut coo = Coo::new(n, n);
    for _ in 0..n_edges {
        let a = rng.gen_range(n);
        let b = rng.gen_range(n);
        if a != b {
            coo.push_sym(a, b, 1.0);
        }
    }
    let mut csr = coo.to_csr();
    // clamp merged duplicate weights back to 1.0 (simple graph)
    for v in &mut csr.values {
        *v = 1.0;
    }
    csr
}

#[test]
fn prop_sym_graph_is_symmetric() {
    forall("undirected construction is symmetric", 64, |rng| {
        let g = arb_sym_graph(rng, 20);
        assert_eq!(g.transpose(), g);
    });
}

#[test]
fn prop_row_norm_stochastic() {
    forall("row normalisation makes rows sum to 1", 64, |rng| {
        let g = arb_sym_graph(rng, 16);
        let n = row_normalize(&g).unwrap();
        for r in 0..n.rows {
            let s: f32 = n.row_vals(r).iter().sum();
            if g.row_nnz(r) > 0 {
                assert!((s - 1.0).abs() < 1e-5);
            } else {
                assert_eq!(s, 0.0);
            }
        }
    });
}

#[test]
fn prop_gcn_norm_properties() {
    forall("gcn norm: symmetric, bounded, diag", 64, |rng| {
        let g = arb_sym_graph(rng, 14);
        let a = gcn_normalize(&g).unwrap();
        a.validate().unwrap();
        assert_eq!(a.transpose(), a);
        for &v in &a.values {
            assert!(v > 0.0 && v <= 1.0 + 1e-6);
        }
        // diagonal of Â is 1/(deg+1) exactly
        let deg = degree_vector(&g);
        let d = a.to_dense();
        for i in 0..14 {
            let expect = 1.0 / (deg[i] + 1.0);
            assert!((d.get(i, i) - expect).abs() < 1e-5);
        }
    });
}

#[test]
fn prop_coalesce_idempotent() {
    forall("sum_duplicates idempotent", 64, |rng| {
        let mut coo = Coo::new(10, 10);
        let n = rng.gen_range(60);
        for _ in 0..n {
            coo.push(rng.gen_range(10), rng.gen_range(10), rng.gen_range_f32(-1.0, 1.0));
        }
        let mut once = coo.clone();
        once.sum_duplicates();
        let mut twice = once.clone();
        twice.sum_duplicates();
        assert_eq!(once.row_idx, twice.row_idx);
        assert_eq!(once.col_idx, twice.col_idx);
        assert_eq!(once.values, twice.values);
    });
}

#[test]
fn prop_nnz_conserved() {
    forall("nnz conserved by conversions", 64, |rng| {
        let g = arb_sym_graph(rng, 18);
        assert_eq!(g.transpose().nnz(), g.nnz());
        assert_eq!(g.to_coo().nnz(), g.nnz());
        assert_eq!(g.to_csc().nnz(), g.nnz());
    });
}
