//! Property tests for the sparse substrate itself (format invariants,
//! normalisation identities). Kernel-level properties live in
//! `kernels::proptests`.

use super::{degree_vector, gcn_normalize, row_normalize, Coo, Csr, Sell, SortedCsr};
use crate::util::check::forall;
use crate::util::rng::Rng;

/// Random undirected simple graph over `n` nodes.
fn arb_sym_graph(rng: &mut Rng, n: usize) -> Csr {
    let n_edges = rng.gen_range(n * 3 + 1);
    let mut coo = Coo::new(n, n);
    for _ in 0..n_edges {
        let a = rng.gen_range(n);
        let b = rng.gen_range(n);
        if a != b {
            coo.push_sym(a, b, 1.0);
        }
    }
    let mut csr = coo.to_csr();
    // clamp merged duplicate weights back to 1.0 (simple graph)
    for v in &mut csr.values {
        *v = 1.0;
    }
    csr
}

#[test]
fn prop_sym_graph_is_symmetric() {
    forall("undirected construction is symmetric", 64, |rng| {
        let g = arb_sym_graph(rng, 20);
        assert_eq!(g.transpose(), g);
    });
}

#[test]
fn prop_row_norm_stochastic() {
    forall("row normalisation makes rows sum to 1", 64, |rng| {
        let g = arb_sym_graph(rng, 16);
        let n = row_normalize(&g).unwrap();
        for r in 0..n.rows {
            let s: f32 = n.row_vals(r).iter().sum();
            if g.row_nnz(r) > 0 {
                assert!((s - 1.0).abs() < 1e-5);
            } else {
                assert_eq!(s, 0.0);
            }
        }
    });
}

#[test]
fn prop_gcn_norm_properties() {
    forall("gcn norm: symmetric, bounded, diag", 64, |rng| {
        let g = arb_sym_graph(rng, 14);
        let a = gcn_normalize(&g).unwrap();
        a.validate().unwrap();
        assert_eq!(a.transpose(), a);
        for &v in &a.values {
            assert!(v > 0.0 && v <= 1.0 + 1e-6);
        }
        // diagonal of Â is 1/(deg+1) exactly
        let deg = degree_vector(&g);
        let d = a.to_dense();
        for i in 0..14 {
            let expect = 1.0 / (deg[i] + 1.0);
            assert!((d.get(i, i) - expect).abs() < 1e-5);
        }
    });
}

#[test]
fn prop_coalesce_idempotent() {
    forall("sum_duplicates idempotent", 64, |rng| {
        let mut coo = Coo::new(10, 10);
        let n = rng.gen_range(60);
        for _ in 0..n {
            coo.push(rng.gen_range(10), rng.gen_range(10), rng.gen_range_f32(-1.0, 1.0));
        }
        let mut once = coo.clone();
        once.sum_duplicates();
        let mut twice = once.clone();
        twice.sum_duplicates();
        assert_eq!(once.row_idx, twice.row_idx);
        assert_eq!(once.col_idx, twice.col_idx);
        assert_eq!(once.values, twice.values);
    });
}

#[test]
fn prop_nnz_conserved() {
    forall("nnz conserved by conversions", 64, |rng| {
        let g = arb_sym_graph(rng, 18);
        assert_eq!(g.transpose().nnz(), g.nnz());
        assert_eq!(g.to_coo().nnz(), g.nnz());
        assert_eq!(g.to_csc().nnz(), g.nnz());
        assert_eq!(Sell::from_csr(&g, 4, 8).nnz(), g.nnz());
        assert_eq!(SortedCsr::from_csr(&g).nnz(), g.nnz());
    });
}

#[test]
fn prop_sell_and_sorted_invert_exactly() {
    // The format axis rests on these being *exact* inverses (bit-for-bit
    // CSR equality), for any graph — including empty rows and graphs
    // whose row count is no multiple of C or σ.
    forall("sell/sorted-csr exact inverses", 64, |rng| {
        let g = arb_sym_graph(rng, 1 + rng.gen_range(30));
        let c = 1 + rng.gen_range(8);
        let sigma = 1 + rng.gen_range(50);
        let sell = Sell::from_csr(&g, c, sigma);
        sell.validate().unwrap();
        assert_eq!(sell.to_csr(), g, "c={c} sigma={sigma}");
        assert_eq!(SortedCsr::from_csr(&g).to_csr(), g);
    });
}

#[test]
fn prop_row_len_stats_consistent_with_histogram() {
    forall("row-length stats ↔ histogram consistency", 64, |rng| {
        let g = arb_sym_graph(rng, 1 + rng.gen_range(24));
        let hist = g.row_len_histogram();
        assert_eq!(hist.iter().sum::<usize>(), g.rows);
        let stats = g.row_len_stats();
        assert!(stats.p50 <= stats.p99);
        assert!(stats.p99 <= stats.max);
        assert!(stats.mean <= stats.max as f64);
        // the histogram's top bucket agrees with max
        if stats.max > 0 {
            let top = hist.len() - 1;
            assert!(stats.max >= 1 << (top - 1), "max {} bucket {top}", stats.max);
            assert!(stats.max < 1 << top);
        }
    });
}
