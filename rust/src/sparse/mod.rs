//! Sparse matrix substrate.
//!
//! iSpLib's matmul interface (paper §3.5) receives the graph in CSR
//! (compressed sparse row) format; the backprop cache (§3.3) additionally
//! needs the transpose, which we keep as a second CSR (equivalently the CSC
//! of the original). Datasets are generated edge-by-edge, so COO is the
//! construction format.
//!
//! Layout choices mirror `pytorch_sparse` (the library the paper patches):
//! `row_ptr: Vec<usize>` of length `rows+1`, column indices sorted within
//! each row, explicit `f32` values (GNN adjacencies are weighted after GCN
//! normalisation).

mod coo;
mod csc;
mod csr;
mod norm;

pub use coo::Coo;
pub use csc::Csc;
pub use csr::Csr;
pub use norm::{degree_counts, degree_vector, gcn_normalize, row_normalize, NormKind};

#[cfg(test)]
mod proptests;
