//! Sparse matrix substrate.
//!
//! iSpLib's matmul interface (paper §3.5) receives the graph in CSR
//! (compressed sparse row) format; the backprop cache (§3.3) additionally
//! needs the transpose, which we keep as a second CSR (equivalently the CSC
//! of the original). Datasets are generated edge-by-edge, so COO is the
//! construction format.
//!
//! Layout choices mirror `pytorch_sparse` (the library the paper patches):
//! `row_ptr: Vec<usize>` of length `rows+1`, column indices sorted within
//! each row, explicit `f32` values (GNN adjacencies are weighted after GCN
//! normalisation).
//!
//! Beyond the kernel-input CSR, the auto-tuner can choose alternative
//! *representations* of the same matrix: [`Sell`] (SELL-C-σ, sliced and
//! window-sorted for branch-free short-row inner loops) and [`SortedCsr`]
//! (globally row-length-sorted CSR). Both are exact row permutations with
//! an exact inverse, so kernels over them stay bitwise-equal to the
//! trusted CSR path — see `sell.rs` for the argument.

mod coo;
mod csc;
mod csr;
mod norm;
mod sell;

pub use coo::Coo;
pub use csc::Csc;
pub use csr::{Csr, EdgeDelta, RowLenStats};
pub use norm::{degree_counts, degree_vector, gcn_normalize, row_normalize, NormKind};
pub use sell::{Sell, SortedCsr};

#[cfg(test)]
mod proptests;
