//! CSC (compressed sparse column).
//!
//! Used by the column-major SpMM-transpose path: `A^T @ G` with `A` in CSR
//! is exactly `spmm` over the CSC view of `A`. The backprop cache prefers a
//! materialised transposed CSR (better locality for the row-streaming
//! kernels), but CSC is kept as a first-class citizen for format-conversion
//! completeness and the format-selection experiments.

use crate::error::{Error, Result};

use super::Csr;

/// Compressed-sparse-column matrix with `f32` values.
#[derive(Clone, Debug, PartialEq)]
pub struct Csc {
    /// Number of rows.
    pub rows: usize,
    /// Number of columns.
    pub cols: usize,
    /// Column offsets, length `cols + 1`.
    pub col_ptr: Vec<usize>,
    /// Row index per non-zero.
    pub row_idx: Vec<usize>,
    /// Value per non-zero.
    pub values: Vec<f32>,
}

impl Csc {
    /// Build from raw parts, validating the invariants (mirror of CSR's).
    pub fn from_parts(
        rows: usize,
        cols: usize,
        col_ptr: Vec<usize>,
        row_idx: Vec<usize>,
        values: Vec<f32>,
    ) -> Result<Self> {
        let m = Csc { rows, cols, col_ptr, row_idx, values };
        m.validate()?;
        Ok(m)
    }

    /// Number of non-zeros.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Row indices of column `c`.
    #[inline]
    pub fn col_rows(&self, c: usize) -> &[usize] {
        &self.row_idx[self.col_ptr[c]..self.col_ptr[c + 1]]
    }

    /// Values of column `c`.
    #[inline]
    pub fn col_vals(&self, c: usize) -> &[f32] {
        &self.values[self.col_ptr[c]..self.col_ptr[c + 1]]
    }

    /// Validate structural invariants.
    pub fn validate(&self) -> Result<()> {
        if self.col_ptr.len() != self.cols + 1 {
            return Err(Error::InvalidSparse(format!(
                "col_ptr len {} != cols+1 {}",
                self.col_ptr.len(),
                self.cols + 1
            )));
        }
        if self.col_ptr[0] != 0 || *self.col_ptr.last().unwrap() != self.nnz() {
            return Err(Error::InvalidSparse("col_ptr endpoints wrong".into()));
        }
        for w in self.col_ptr.windows(2) {
            if w[1] < w[0] {
                return Err(Error::InvalidSparse("col_ptr not monotone".into()));
            }
        }
        for c in 0..self.cols {
            let rows = self.col_rows(c);
            for w in rows.windows(2) {
                if w[1] <= w[0] {
                    return Err(Error::InvalidSparse(format!(
                        "col {c}: rows not strictly increasing"
                    )));
                }
            }
            if let Some(&r) = rows.last() {
                if r >= self.rows {
                    return Err(Error::InvalidSparse(format!(
                        "col {c}: row {r} >= rows {}",
                        self.rows
                    )));
                }
            }
        }
        Ok(())
    }

    /// Convert to CSR. The CSC of `A` is structurally the CSR of `A^T`, so
    /// conversion is a transpose of the reinterpreted matrix.
    pub fn to_csr(&self) -> Csr {
        // Reinterpret (col_ptr,row_idx) as a CSR of A^T, then transpose.
        let at = Csr::from_parts_unchecked(
            self.cols,
            self.rows,
            self.col_ptr.clone(),
            self.row_idx.clone(),
            self.values.clone(),
        );
        at.transpose()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csr_csc_roundtrip() {
        let m = Csr::from_parts(3, 4, vec![0, 2, 3, 5], vec![0, 3, 2, 1, 3], vec![
            1.0, 2.0, 3.0, 4.0, 5.0,
        ])
        .unwrap();
        let csc = m.to_csc();
        csc.validate().unwrap();
        assert_eq!(csc.to_csr(), m);
    }

    #[test]
    fn col_accessors() {
        let m = Csr::from_parts(2, 2, vec![0, 2, 3], vec![0, 1, 0], vec![1.0, 2.0, 3.0]).unwrap();
        let csc = m.to_csc();
        assert_eq!(csc.col_rows(0), &[0, 1]);
        assert_eq!(csc.col_vals(0), &[1.0, 3.0]);
        assert_eq!(csc.col_rows(1), &[0]);
    }

    #[test]
    fn validate_rejects_garbage() {
        assert!(Csc::from_parts(2, 2, vec![0, 1], vec![0], vec![1.0]).is_err());
        assert!(Csc::from_parts(2, 2, vec![0, 1, 1], vec![9], vec![1.0]).is_err());
    }
}
