//! COO (coordinate / triplet) sparse format — the construction format.
//!
//! Graph generators emit edges one at a time; COO accumulates them and is
//! then converted once to CSR for the kernels. Duplicate handling is
//! explicit: [`Coo::sum_duplicates`] mirrors what `torch_sparse.coalesce`
//! does for multigraph edge lists.

use crate::error::{Error, Result};

use super::Csr;

/// Coordinate-format sparse matrix: parallel `(row, col, val)` triplets.
#[derive(Clone, Debug, Default)]
pub struct Coo {
    /// Number of rows.
    pub rows: usize,
    /// Number of columns.
    pub cols: usize,
    /// Row index per entry.
    pub row_idx: Vec<usize>,
    /// Column index per entry.
    pub col_idx: Vec<usize>,
    /// Value per entry.
    pub values: Vec<f32>,
}

impl Coo {
    /// Empty matrix of the given shape.
    pub fn new(rows: usize, cols: usize) -> Self {
        Coo { rows, cols, row_idx: Vec::new(), col_idx: Vec::new(), values: Vec::new() }
    }

    /// Empty matrix with pre-allocated capacity for `nnz` entries.
    pub fn with_capacity(rows: usize, cols: usize, nnz: usize) -> Self {
        Coo {
            rows,
            cols,
            row_idx: Vec::with_capacity(nnz),
            col_idx: Vec::with_capacity(nnz),
            values: Vec::with_capacity(nnz),
        }
    }

    /// Build from parallel triplet vectors (validated).
    pub fn from_triplets(
        rows: usize,
        cols: usize,
        row_idx: Vec<usize>,
        col_idx: Vec<usize>,
        values: Vec<f32>,
    ) -> Result<Self> {
        if row_idx.len() != col_idx.len() || row_idx.len() != values.len() {
            return Err(Error::InvalidSparse(format!(
                "triplet arrays disagree: {} rows, {} cols, {} vals",
                row_idx.len(),
                col_idx.len(),
                values.len()
            )));
        }
        if let Some(&r) = row_idx.iter().max() {
            if r >= rows {
                return Err(Error::InvalidSparse(format!("row index {r} >= rows {rows}")));
            }
        }
        if let Some(&c) = col_idx.iter().max() {
            if c >= cols {
                return Err(Error::InvalidSparse(format!("col index {c} >= cols {cols}")));
            }
        }
        Ok(Coo { rows, cols, row_idx, col_idx, values })
    }

    /// Number of stored entries (including any duplicates).
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Append one entry (debug-checked bounds).
    #[inline]
    pub fn push(&mut self, row: usize, col: usize, val: f32) {
        debug_assert!(row < self.rows && col < self.cols);
        self.row_idx.push(row);
        self.col_idx.push(col);
        self.values.push(val);
    }

    /// Append the symmetric pair `(r,c)` and `(c,r)` — undirected edges.
    pub fn push_sym(&mut self, r: usize, c: usize, val: f32) {
        self.push(r, c, val);
        if r != c {
            self.push(c, r, val);
        }
    }

    /// Sort triplets by `(row, col)` and merge duplicates by summing values.
    /// Equivalent to `torch_sparse.coalesce`.
    pub fn sum_duplicates(&mut self) {
        if self.nnz() == 0 {
            return;
        }
        let mut order: Vec<usize> = (0..self.nnz()).collect();
        order.sort_unstable_by_key(|&i| (self.row_idx[i], self.col_idx[i]));

        let mut row_out = Vec::with_capacity(self.nnz());
        let mut col_out = Vec::with_capacity(self.nnz());
        let mut val_out = Vec::with_capacity(self.nnz());
        for &i in &order {
            let (r, c, v) = (self.row_idx[i], self.col_idx[i], self.values[i]);
            if let (Some(&lr), Some(&lc)) = (row_out.last(), col_out.last()) {
                if lr == r && lc == c {
                    *val_out.last_mut().unwrap() += v;
                    continue;
                }
            }
            row_out.push(r);
            col_out.push(c);
            val_out.push(v);
        }
        self.row_idx = row_out;
        self.col_idx = col_out;
        self.values = val_out;
    }

    /// Convert to CSR. Duplicates are merged (summed) first.
    pub fn to_csr(&self) -> Csr {
        let mut coo = self.clone();
        coo.sum_duplicates();
        let mut row_ptr = vec![0usize; coo.rows + 1];
        for &r in &coo.row_idx {
            row_ptr[r + 1] += 1;
        }
        for i in 0..coo.rows {
            row_ptr[i + 1] += row_ptr[i];
        }
        // After sum_duplicates the triplets are already (row, col)-sorted,
        // so col_idx/values can be taken as-is.
        Csr::from_parts_unchecked(coo.rows, coo.cols, row_ptr, coo.col_idx, coo.values)
    }

    /// Transpose (swap row/col index vectors — O(1) semantics, O(nnz) clone).
    pub fn transpose(&self) -> Coo {
        Coo {
            rows: self.cols,
            cols: self.rows,
            row_idx: self.col_idx.clone(),
            col_idx: self.row_idx.clone(),
            values: self.values.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_nnz() {
        let mut c = Coo::new(3, 3);
        c.push(0, 1, 1.0);
        c.push(2, 0, 2.0);
        assert_eq!(c.nnz(), 2);
    }

    #[test]
    fn push_sym_skips_self_loop_double() {
        let mut c = Coo::new(3, 3);
        c.push_sym(1, 1, 5.0);
        assert_eq!(c.nnz(), 1);
        c.push_sym(0, 2, 1.0);
        assert_eq!(c.nnz(), 3);
    }

    #[test]
    fn from_triplets_validates() {
        assert!(Coo::from_triplets(2, 2, vec![0], vec![0, 1], vec![1.0]).is_err());
        assert!(Coo::from_triplets(2, 2, vec![2], vec![0], vec![1.0]).is_err());
        assert!(Coo::from_triplets(2, 2, vec![0], vec![5], vec![1.0]).is_err());
        assert!(Coo::from_triplets(2, 2, vec![1], vec![1], vec![1.0]).is_ok());
    }

    #[test]
    fn sum_duplicates_merges_and_sorts() {
        let mut c =
            Coo::from_triplets(2, 3, vec![1, 0, 1], vec![2, 1, 2], vec![1.0, 3.0, 4.0]).unwrap();
        c.sum_duplicates();
        assert_eq!(c.row_idx, vec![0, 1]);
        assert_eq!(c.col_idx, vec![1, 2]);
        assert_eq!(c.values, vec![3.0, 5.0]);
    }

    #[test]
    fn to_csr_small() {
        let c = Coo::from_triplets(
            3,
            3,
            vec![0, 0, 2, 1],
            vec![1, 2, 0, 1],
            vec![1.0, 2.0, 3.0, 4.0],
        )
        .unwrap();
        let csr = c.to_csr();
        assert_eq!(csr.row_ptr, vec![0, 2, 3, 4]);
        assert_eq!(csr.col_idx, vec![1, 2, 1, 0]);
        assert_eq!(csr.values, vec![1.0, 2.0, 4.0, 3.0]);
        csr.validate().unwrap();
    }

    #[test]
    fn transpose_swaps() {
        let c = Coo::from_triplets(2, 3, vec![0, 1], vec![2, 0], vec![1.0, 2.0]).unwrap();
        let t = c.transpose();
        assert_eq!(t.rows, 3);
        assert_eq!(t.cols, 2);
        assert_eq!(t.row_idx, vec![2, 0]);
        assert_eq!(t.col_idx, vec![0, 1]);
    }

    #[test]
    fn empty_to_csr() {
        let c = Coo::new(4, 4);
        let csr = c.to_csr();
        assert_eq!(csr.nnz(), 0);
        assert_eq!(csr.row_ptr, vec![0; 5]);
    }
}
