//! Reverse-mode autodiff tape over dense + sparse matrix ops.
//!
//! This is the substrate that plays PyTorch-autograd's role in the paper's
//! baselines: a dynamic tape recording forward ops, then a reverse sweep
//! producing gradients. The GNN trainer builds every model (GCN, SAGE, GIN)
//! on this tape, and the tape's `spmm` node is where iSpLib plugs in:
//!
//! * the **forward** kernel is resolved through the global
//!   [`KernelRegistry`](crate::autotune::KernelRegistry) (so `patch()` /
//!   the tuner control it),
//! * the **backward** needs `Aᵀ`; a cached operand carries it
//!   pre-transposed (paper §3.3), an uncached operand recomputes the
//!   transpose on *every* backward step — the two cost models the
//!   `cache_backprop` bench compares.

mod ops;
mod tape;

pub use ops::{context_graph_id, SpmmImpl, SpmmOperand};
pub use tape::{Tape, Var};
