//! The reverse-mode tape.
//!
//! A [`Tape`] records a DAG of matrix ops during the forward pass; calling
//! [`Tape::backward`] runs the reverse sweep, accumulating gradients into
//! every node. Handles ([`Var`]) are indices into the tape, so models are
//! written in straight-line style:
//!
//! ```
//! use isplib::autodiff::{SpmmOperand, Tape};
//! use isplib::dense::Dense;
//! use isplib::sparse::Coo;
//!
//! let mut coo = Coo::new(2, 2);
//! coo.push_sym(0, 1, 1.0);
//! let graph = SpmmOperand::cached(coo.to_csr(), "doc");
//!
//! let mut tape = Tape::new(1);
//! let x = tape.input(Dense::from_vec(2, 2, vec![1., 2., 3., 4.]).unwrap());
//! let w = tape.input(Dense::from_vec(2, 2, vec![0.1, 0.2, 0.3, 0.4]).unwrap());
//! let h = tape.matmul(x, w).unwrap();
//! let h = tape.spmm(&graph, h).unwrap();
//! let loss = tape.softmax_xent(h, &[0, 1], None).unwrap();
//! tape.backward(loss).unwrap();
//! assert!(tape.grad(w).is_some());
//! ```
//!
//! Supported ops cover what the paper's GNN zoo needs: dense matmul, SpMM
//! (sum semiring — what GCN/SAGE/GIN training uses), bias broadcast, ReLU,
//! residual add, constant scale, and masked softmax cross-entropy.

use crate::dense::Dense;
use crate::error::{Error, Result};
use crate::kernels::{
    fused_relu_epilogue, spmm_fused_relu_sharded, spmm_sharded, KernelWorkspace, Semiring,
};

use crate::autotune::KernelRegistry;

use super::ops::SpmmImpl;
use super::SpmmOperand;

/// Handle to a tape node.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Var(usize);

enum Op {
    Input,
    /// `C = A @ B`
    Matmul(Var, Var),
    /// `Y = spmm(A, X)`, sum semiring, kernel via registry
    Spmm { operand: SpmmOperand, x: Var },
    /// `Y = relu(spmm(A, X) + 1·bᵀ)` in one fused kernel pass — the plan
    /// fusion pass's target op ([`crate::plan`]). `bias` is optional: a
    /// bare `Spmm→Relu` edge fuses without one.
    SpmmFusedRelu { operand: SpmmOperand, x: Var, bias: Option<Var> },
    /// `Y = X + 1·bᵀ` (bias is a 1×C node)
    AddBias(Var, Var),
    /// `Y = max(X, 0)`
    Relu(Var),
    /// `Y = A + B`
    Add(Var, Var),
    /// `Y = αX`
    Scale(Var, f32),
    /// scalar loss node: masked mean softmax cross-entropy
    SoftmaxXent { logits: Var, labels: Vec<usize>, mask: Option<Vec<bool>>, probs: Dense },
}

struct Node {
    op: Op,
    value: std::sync::Arc<Dense>,
    grad: Option<Dense>,
    /// Does any gradient need to flow into this node? Inputs opt in
    /// (parameters yes, constant features no); op nodes inherit the OR of
    /// their operands. Backward skips gradient math into no-grad operands —
    /// for a GCN this elides the full dX GEMM for the feature matrix.
    needs_grad: bool,
}

/// Reverse-mode tape. One tape per training step (cheap: nodes are moved
/// values, not copies of parameters).
pub struct Tape {
    nodes: Vec<Node>,
    threads: usize,
    /// When set, dense-op node buffers (matmul/bias/relu/add) are drawn
    /// from this workspace's pool and every node buffer is recycled back
    /// into it as the tape drops, so the next epoch's tape allocates
    /// (almost) nothing.
    workspace: Option<std::sync::Arc<KernelWorkspace>>,
}

impl Tape {
    /// New tape; `threads` is the budget for sparse kernels (1 = serial).
    pub fn new(threads: usize) -> Self {
        Tape { nodes: Vec::new(), threads, workspace: None }
    }

    /// New tape whose node buffers are returned to `workspace` on drop —
    /// the trainer pairs this with operands carrying the same workspace so
    /// each epoch's outputs become the next epoch's buffers.
    pub fn with_workspace(threads: usize, workspace: std::sync::Arc<KernelWorkspace>) -> Self {
        Tape { nodes: Vec::new(), threads, workspace: Some(workspace) }
    }

    fn push_with(&mut self, op: Op, value: std::sync::Arc<Dense>, needs_grad: bool) -> Var {
        self.nodes.push(Node { op, value, grad: None, needs_grad });
        Var(self.nodes.len() - 1)
    }

    fn push(&mut self, op: Op, value: Dense) -> Var {
        let needs_grad = self.op_needs_grad(&op);
        self.push_with(op, std::sync::Arc::new(value), needs_grad)
    }

    fn op_needs_grad(&self, op: &Op) -> bool {
        let ng = |v: &Var| self.nodes[v.0].needs_grad;
        match op {
            Op::Input => true,
            Op::Matmul(a, b) => ng(a) || ng(b),
            Op::Spmm { x, .. } => ng(x),
            Op::SpmmFusedRelu { x, bias, .. } => ng(x) || bias.as_ref().map(ng).unwrap_or(false),
            Op::AddBias(x, b) => ng(x) || ng(b),
            Op::Relu(x) | Op::Scale(x, _) => ng(x),
            Op::Add(a, b) => ng(a) || ng(b),
            Op::SoftmaxXent { logits, .. } => ng(logits),
        }
    }

    /// Register a trainable input/parameter node (gradients flow into it).
    pub fn input(&mut self, value: Dense) -> Var {
        self.push_with(Op::Input, std::sync::Arc::new(value), true)
    }

    /// Register a *constant* input node: no gradient is ever computed into
    /// it, and backward skips the work that would produce one. Use for the
    /// feature matrix.
    pub fn input_no_grad(&mut self, value: std::sync::Arc<Dense>) -> Var {
        self.push_with(Op::Input, value, false)
    }

    /// Value of a node.
    pub fn value(&self, v: Var) -> &Dense {
        &self.nodes[v.0].value
    }

    /// Gradient of a node (after [`Tape::backward`]).
    pub fn grad(&self, v: Var) -> Option<&Dense> {
        self.nodes[v.0].grad.as_ref()
    }

    /// Allocate a node-value matrix: pooled (pre-zeroed) from the attached
    /// workspace, else fresh. Paired with the recycling in `Drop`, this
    /// extends the zero-steady-state-allocation story from the SpMM nodes
    /// to the dense ops — matmul/bias/relu/add outputs of one epoch become
    /// the next epoch's buffers.
    fn alloc_value(&self, rows: usize, cols: usize) -> Dense {
        match &self.workspace {
            Some(ws) => ws.take_dense(rows, cols),
            None => Dense::zeros(rows, cols),
        }
    }

    /// Dense matmul node. With a workspace attached the output buffer
    /// comes from the recycle pool ([`Dense::matmul_into`] is
    /// bitwise-equal to [`Dense::matmul`]).
    pub fn matmul(&mut self, a: Var, b: Var) -> Result<Var> {
        let av = std::sync::Arc::clone(&self.nodes[a.0].value);
        let bv = std::sync::Arc::clone(&self.nodes[b.0].value);
        if av.cols != bv.rows {
            return Err(Error::ShapeMismatch(format!(
                "matmul: {}x{} @ {}x{}",
                av.rows, av.cols, bv.rows, bv.cols
            )));
        }
        let mut value = self.alloc_value(av.rows, bv.cols);
        av.matmul_into(&bv, &mut value)?;
        Ok(self.push(Op::Matmul(a, b), value))
    }

    /// Forward aggregation for one SpMM call — the single encoding of the
    /// strategy dispatch shared by the plain and fused SpMM nodes. Kernel
    /// operands resolve their routing through the global registry at call
    /// time, so `patch()`/tuning affect live training; EdgeWise/Dense
    /// operands model the PT2-MP and vanilla-dense baselines.
    fn spmm_forward_value(&self, operand: &SpmmOperand, xv: &Dense) -> Result<Dense> {
        match operand.impl_kind {
            SpmmImpl::Kernel => {
                let choice =
                    KernelRegistry::global().resolve(&operand.context, xv.cols, Semiring::Sum);
                let ws = operand.workspace.as_deref().map(|w| (w, operand.graph_key()));
                // sharded dispatch — delegates to the flat kernel when the
                // operand is unsharded (shards ≤ 1), so this is the single
                // SpMM routing for both modes
                spmm_sharded(
                    &operand.a,
                    xv,
                    Semiring::Sum,
                    choice,
                    self.threads,
                    ws,
                    operand.shards,
                )
            }
            SpmmImpl::EdgeWise => operand.edgewise_forward(xv),
            SpmmImpl::Dense => operand.dense.as_ref().expect("dense operand").matmul(xv),
        }
    }

    /// Backward of one SpMM call: `dX = spmm(Aᵀ, dY)` under the operand's
    /// strategy — shared by the plain and fused SpMM nodes so their
    /// gradients are computed by identical code.
    fn spmm_backward_value(&self, operand: &SpmmOperand, gout: &Dense) -> Result<Dense> {
        match operand.impl_kind {
            SpmmImpl::Kernel => {
                // dX = spmm(Aᵀ, G) — Aᵀ cached or recomputed (§3.3)
                let at = operand.transpose();
                let choice =
                    KernelRegistry::global().resolve(&operand.context, gout.cols, Semiring::Sum);
                // Aᵀ is a different matrix than A: its partition caches
                // under the derived transpose id.
                let ws = operand
                    .workspace
                    .as_deref()
                    .map(|w| (w, operand.graph_key().transpose()));
                // Aᵀ shards under its own plan (different matrix, different
                // degree profile), cached under the transpose identity
                spmm_sharded(&at, gout, Semiring::Sum, choice, self.threads, ws, operand.shards)
            }
            SpmmImpl::EdgeWise => operand.edgewise_backward(gout),
            SpmmImpl::Dense => operand.dense.as_ref().expect("dense operand").t_matmul(gout),
        }
    }

    /// SpMM node (sum semiring); see [`Tape::spmm_forward_value`] for the
    /// strategy dispatch.
    pub fn spmm(&mut self, operand: &SpmmOperand, x: Var) -> Result<Var> {
        let xv = std::sync::Arc::clone(&self.nodes[x.0].value);
        let value = self.spmm_forward_value(operand, &xv)?;
        Ok(self.push(Op::Spmm { operand: operand.clone(), x }, value))
    }

    /// Fused `relu(spmm(A, X) + bias)` node — one kernel pass on the
    /// forward (the FusedMM epilogue fusion,
    /// [`spmm_fused_relu_with_workspace`]), one masked sweep on the
    /// backward. Gradients are bitwise-identical to the unfused
    /// `spmm → add_bias → relu` chain: the relu mask read off the fused
    /// *output* (`y > 0`) is exactly the mask read off the unfused relu
    /// *input* (`x > 0`), because `relu` is the identity on positives and
    /// pins everything else to zero. Baseline (EdgeWise/Dense) operands
    /// aggregate their usual way and apply the epilogue afterwards — the
    /// fused *op* exists on every backend, the fused *loop* only on the
    /// kernel path.
    pub fn spmm_fused_relu(
        &mut self,
        operand: &SpmmOperand,
        x: Var,
        bias: Option<Var>,
    ) -> Result<Var> {
        let xv = std::sync::Arc::clone(&self.nodes[x.0].value);
        let bv = match bias {
            Some(b) => {
                let bv = std::sync::Arc::clone(&self.nodes[b.0].value);
                if bv.rows != 1 {
                    return Err(Error::ShapeMismatch(format!(
                        "fused bias must be 1xC, got {}x{}",
                        bv.rows, bv.cols
                    )));
                }
                if bv.cols != xv.cols {
                    return Err(Error::ShapeMismatch(format!(
                        "fused bias: len {} vs cols {}",
                        bv.cols, xv.cols
                    )));
                }
                Some(bv)
            }
            None => None,
        };
        let bias_row = bv.as_ref().map(|b| &b.data[..]);
        let value = match operand.impl_kind {
            SpmmImpl::Kernel => {
                // the fused family is format-routed exactly like the plain
                // one: the tuner's joint (format, fuse) decision resolves
                // through the registry, so a SELL- or sorted-CSR-tuned
                // graph keeps its layout through the fused epilogue
                let choice =
                    KernelRegistry::global().resolve(&operand.context, xv.cols, Semiring::Sum);
                let ws = operand.workspace.as_deref().map(|w| (w, operand.graph_key()));
                spmm_fused_relu_sharded(
                    &operand.a,
                    &xv,
                    bias_row,
                    choice,
                    self.threads,
                    ws,
                    operand.shards,
                )?
            }
            _ => {
                let mut y = self.spmm_forward_value(operand, &xv)?;
                fused_relu_epilogue(&mut y, bias_row)?;
                y
            }
        };
        Ok(self.push(Op::SpmmFusedRelu { operand: operand.clone(), x, bias }, value))
    }

    /// Bias-broadcast node: `X + b` with `b` a 1×C parameter. Output
    /// buffer pooled when a workspace is attached.
    pub fn add_bias(&mut self, x: Var, bias: Var) -> Result<Var> {
        let xv = std::sync::Arc::clone(&self.nodes[x.0].value);
        let b = std::sync::Arc::clone(&self.nodes[bias.0].value);
        if b.rows != 1 {
            return Err(Error::ShapeMismatch(format!("bias must be 1xC, got {}x{}", b.rows, b.cols)));
        }
        if b.cols != xv.cols {
            return Err(Error::ShapeMismatch(format!("bias: len {} vs cols {}", b.cols, xv.cols)));
        }
        let mut value = self.alloc_value(xv.rows, xv.cols);
        xv.add_row_broadcast_into(&b.data, &mut value)?;
        Ok(self.push(Op::AddBias(x, bias), value))
    }

    /// ReLU node. Output buffer pooled when a workspace is attached.
    pub fn relu(&mut self, x: Var) -> Result<Var> {
        let xv = std::sync::Arc::clone(&self.nodes[x.0].value);
        let mut value = self.alloc_value(xv.rows, xv.cols);
        xv.relu_into(&mut value)?;
        Ok(self.push(Op::Relu(x), value))
    }

    /// Elementwise add node. Output buffer pooled when a workspace is
    /// attached.
    pub fn add(&mut self, a: Var, b: Var) -> Result<Var> {
        let av = std::sync::Arc::clone(&self.nodes[a.0].value);
        let bv = std::sync::Arc::clone(&self.nodes[b.0].value);
        if av.rows != bv.rows || av.cols != bv.cols {
            return Err(Error::ShapeMismatch(format!(
                "elementwise: {}x{} vs {}x{}",
                av.rows, av.cols, bv.rows, bv.cols
            )));
        }
        let mut value = self.alloc_value(av.rows, av.cols);
        av.add_into(&bv, &mut value)?;
        Ok(self.push(Op::Add(a, b), value))
    }

    /// Constant-scale node `αX` (GIN's `(1+ε)·x` term).
    pub fn scale(&mut self, x: Var, alpha: f32) -> Result<Var> {
        let mut value: Dense = (*self.nodes[x.0].value).clone();
        value.scale(alpha);
        Ok(self.push(Op::Scale(x, alpha), value))
    }

    /// Masked mean softmax cross-entropy. `labels[r]` is the class of row
    /// `r`; rows where `mask` is false are excluded (the train split).
    /// Returns a scalar (1×1) node.
    pub fn softmax_xent(&mut self, logits: Var, labels: &[usize], mask: Option<&[bool]>) -> Result<Var> {
        let z = &self.nodes[logits.0].value;
        if labels.len() != z.rows {
            return Err(Error::ShapeMismatch(format!(
                "labels len {} vs logits rows {}",
                labels.len(),
                z.rows
            )));
        }
        if let Some(m) = mask {
            if m.len() != z.rows {
                return Err(Error::ShapeMismatch(format!(
                    "mask len {} vs logits rows {}",
                    m.len(),
                    z.rows
                )));
            }
        }
        let mut probs = Dense::zeros(z.rows, z.cols);
        let mut loss = 0.0f64;
        let mut count = 0usize;
        for r in 0..z.rows {
            let active = mask.map(|m| m[r]).unwrap_or(true);
            let row = z.row(r);
            let maxv = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            // exponentiate straight into the probs row (no per-row alloc)
            let prow = probs.row_mut(r);
            let mut sum = 0.0f32;
            for (p, &v) in prow.iter_mut().zip(row.iter()) {
                let e = (v - maxv).exp();
                *p = e;
                sum += e;
            }
            let inv = 1.0 / sum;
            for p in prow.iter_mut() {
                *p *= inv;
            }
            if active {
                if labels[r] >= z.cols {
                    return Err(Error::ShapeMismatch(format!(
                        "label {} out of range for {} classes",
                        labels[r], z.cols
                    )));
                }
                let p = probs.get(r, labels[r]).max(1e-12);
                loss -= (p as f64).ln();
                count += 1;
            }
        }
        let count = count.max(1);
        let value = Dense::from_vec(1, 1, vec![(loss / count as f64) as f32])?;
        Ok(self.push(
            Op::SoftmaxXent {
                logits,
                labels: labels.to_vec(),
                mask: mask.map(|m| m.to_vec()),
                probs,
            },
            value,
        ))
    }

    fn accumulate(&mut self, v: Var, g: Dense) {
        match &mut self.nodes[v.0].grad {
            Some(existing) => existing.axpy(1.0, &g).expect("grad shape"),
            slot @ None => *slot = Some(g),
        }
    }

    /// Reverse sweep from a scalar loss node. Gradients accumulate into
    /// every reachable node; read them back with [`Tape::grad`].
    pub fn backward(&mut self, loss: Var) -> Result<()> {
        let n = std::sync::Arc::clone(&self.nodes[loss.0].value);
        if n.rows != 1 || n.cols != 1 {
            return Err(Error::ShapeMismatch("backward() needs a scalar loss node".into()));
        }
        self.nodes[loss.0].grad = Some(Dense::from_vec(1, 1, vec![1.0])?);

        for i in (0..=loss.0).rev() {
            let Some(gout) = self.nodes[i].grad.clone() else { continue };
            // take op metadata out to appease the borrow checker
            match &self.nodes[i].op {
                Op::Input => {}
                Op::Matmul(a, b) => {
                    let (a, b) = (*a, *b);
                    // dA = G Bᵀ ; dB = Aᵀ G — each computed only when the
                    // operand participates in training (skips e.g. the dX
                    // GEMM for constant features)
                    if self.nodes[a.0].needs_grad {
                        let bv = std::sync::Arc::clone(&self.nodes[b.0].value);
                        let da = gout.matmul_t(&bv)?;
                        self.accumulate(a, da);
                    }
                    if self.nodes[b.0].needs_grad {
                        let av = std::sync::Arc::clone(&self.nodes[a.0].value);
                        let db = av.t_matmul(&gout)?;
                        self.accumulate(b, db);
                    }
                }
                Op::Spmm { operand, x } => {
                    let (operand, x) = (operand.clone(), *x);
                    if !self.nodes[x.0].needs_grad {
                        continue;
                    }
                    let dx = self.spmm_backward_value(&operand, &gout)?;
                    self.accumulate(x, dx);
                }
                Op::SpmmFusedRelu { operand, x, bias } => {
                    let (operand, x, bias) = (operand.clone(), *x, *bias);
                    // relu mask off the fused output: y == 0 ⟺ the unfused
                    // pre-relu value was ≤ 0 (identical to the unfused
                    // chain's mask, which reads the relu input)
                    let value = std::sync::Arc::clone(&self.nodes[i].value);
                    let mut masked = gout.clone();
                    for (d, &v) in masked.data.iter_mut().zip(value.data.iter()) {
                        if v <= 0.0 {
                            *d = 0.0;
                        }
                    }
                    if let Some(b) = bias {
                        if self.nodes[b.0].needs_grad {
                            let db = Dense::from_vec(1, masked.cols, masked.col_sum())?;
                            self.accumulate(b, db);
                        }
                    }
                    if self.nodes[x.0].needs_grad {
                        let dx = self.spmm_backward_value(&operand, &masked)?;
                        self.accumulate(x, dx);
                    }
                }
                Op::AddBias(x, bias) => {
                    let (x, bias) = (*x, *bias);
                    let db = Dense::from_vec(1, gout.cols, gout.col_sum())?;
                    self.accumulate(x, gout.clone());
                    self.accumulate(bias, db);
                }
                Op::Relu(x) => {
                    let x = *x;
                    let xv = &self.nodes[x.0].value;
                    let mut dx = gout.clone();
                    for (d, &v) in dx.data.iter_mut().zip(xv.data.iter()) {
                        if v <= 0.0 {
                            *d = 0.0;
                        }
                    }
                    self.accumulate(x, dx);
                }
                Op::Add(a, b) => {
                    let (a, b) = (*a, *b);
                    self.accumulate(a, gout.clone());
                    self.accumulate(b, gout);
                }
                Op::Scale(x, alpha) => {
                    let (x, alpha) = (*x, *alpha);
                    let mut dx = gout.clone();
                    dx.scale(alpha);
                    self.accumulate(x, dx);
                }
                Op::SoftmaxXent { logits, labels, mask, probs } => {
                    let logits = *logits;
                    let labels = labels.clone();
                    let mask = mask.clone();
                    let probs = probs.clone();
                    let scale = gout.get(0, 0);
                    let count = match &mask {
                        Some(m) => m.iter().filter(|&&b| b).count().max(1),
                        None => probs.rows.max(1),
                    } as f32;
                    let mut dz = Dense::zeros(probs.rows, probs.cols);
                    for r in 0..probs.rows {
                        let active = mask.as_ref().map(|m| m[r]).unwrap_or(true);
                        if !active {
                            continue;
                        }
                        for c in 0..probs.cols {
                            let onehot = if labels[r] == c { 1.0 } else { 0.0 };
                            dz.set(r, c, scale * (probs.get(r, c) - onehot) / count);
                        }
                    }
                    self.accumulate(logits, dz);
                }
            }
        }
        Ok(())
    }
}

impl Drop for Tape {
    fn drop(&mut self) {
        let Some(ws) = self.workspace.take() else { return };
        for node in self.nodes.drain(..) {
            if let Some(g) = node.grad {
                ws.recycle(g.data);
            }
            // values shared outside the tape (e.g. the trainer's feature
            // matrix) keep their Arc and are skipped
            if let Ok(value) = std::sync::Arc::try_unwrap(node.value) {
                ws.recycle(value.data);
            }
            if let Op::SoftmaxXent { probs, .. } = node.op {
                ws.recycle(probs.data);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Coo;
    use crate::sparse::Csr;
    use crate::util::rng::Rng;

    fn graph(n: usize, seed: u64) -> Csr {
        let mut rng = Rng::seed_from_u64(seed);
        let mut coo = Coo::new(n, n);
        for r in 0..n {
            for _ in 0..3 {
                coo.push(r, rng.gen_range(n), rng.gen_range_f32(0.2, 1.0));
            }
        }
        coo.to_csr()
    }

    /// Finite-difference gradient check against the tape for a 1-layer GCN.
    fn fd_check(cached: bool) {
        let n = 8;
        let fin = 5;
        let classes = 3;
        let a = graph(n, 61);
        let mut rng = Rng::seed_from_u64(62);
        let x0 = Dense::uniform(n, fin, 1.0, &mut rng);
        let w0 = Dense::uniform(fin, classes, 0.5, &mut rng);
        let labels: Vec<usize> = (0..n).map(|i| i % classes).collect();

        let build = |w: &Dense| -> (Tape, Var, Var) {
            let operand = if cached {
                SpmmOperand::cached(a.clone(), "fd")
            } else {
                SpmmOperand::uncached(a.clone(), "fd")
            };
            let mut tape = Tape::new(1);
            let x = tape.input(x0.clone());
            let wv = tape.input(w.clone());
            let h = tape.matmul(x, wv).unwrap();
            let h = tape.spmm(&operand, h).unwrap();
            let loss = tape.softmax_xent(h, &labels, None).unwrap();
            (tape, wv, loss)
        };

        let (mut tape, wv, loss) = build(&w0);
        tape.backward(loss).unwrap();
        let analytic = tape.grad(wv).unwrap().clone();

        let eps = 1e-2f32;
        for idx in [0usize, 3, 7, fin * classes - 1] {
            let mut wp = w0.clone();
            wp.data[idx] += eps;
            let (tp, _, lp) = build(&wp);
            let mut wm = w0.clone();
            wm.data[idx] -= eps;
            let (tm, _, lm) = build(&wm);
            let fd = (tp.value(lp).get(0, 0) - tm.value(lm).get(0, 0)) / (2.0 * eps);
            let an = analytic.data[idx];
            assert!(
                (fd - an).abs() < 2e-2 * (1.0 + an.abs()),
                "idx {idx}: fd {fd} vs analytic {an} (cached={cached})"
            );
        }
    }

    #[test]
    fn gradcheck_cached_spmm() {
        fd_check(true);
    }

    #[test]
    fn gradcheck_uncached_spmm() {
        fd_check(false);
    }

    #[test]
    fn cached_and_uncached_grads_identical() {
        let a = graph(10, 63);
        let mut rng = Rng::seed_from_u64(64);
        let x0 = Dense::uniform(10, 4, 1.0, &mut rng);
        let labels: Vec<usize> = (0..10).map(|i| i % 2).collect();
        let run = |operand: SpmmOperand| {
            let mut tape = Tape::new(1);
            let x = tape.input(x0.clone());
            let h = tape.spmm(&operand, x).unwrap();
            let loss = tape.softmax_xent(h, &labels, None).unwrap();
            tape.backward(loss).unwrap();
            tape.grad(x).unwrap().clone()
        };
        let g1 = run(SpmmOperand::cached(a.clone(), "t"));
        let g2 = run(SpmmOperand::uncached(a, "t"));
        assert!(g1.allclose(&g2, 0.0));
    }

    #[test]
    fn relu_masks_gradient() {
        let mut tape = Tape::new(1);
        let x = tape.input(Dense::from_vec(2, 2, vec![-1.0, 2.0, 3.0, -4.0]).unwrap());
        let r = tape.relu(x).unwrap();
        let loss = tape.softmax_xent(r, &[0, 1], None).unwrap();
        tape.backward(loss).unwrap();
        let g = tape.grad(x).unwrap();
        assert_eq!(g.get(0, 0), 0.0); // x<0 → no grad
        assert_eq!(g.get(1, 1), 0.0);
        assert!(g.get(0, 1) != 0.0);
    }

    #[test]
    fn bias_grad_is_column_sum() {
        let mut tape = Tape::new(1);
        let x = tape.input(Dense::from_vec(3, 2, vec![0.5; 6]).unwrap());
        let b = tape.input(Dense::from_vec(1, 2, vec![0.0, 0.0]).unwrap());
        let h = tape.add_bias(x, b).unwrap();
        let loss = tape.softmax_xent(h, &[0, 1, 0], None).unwrap();
        tape.backward(loss).unwrap();
        let gb = tape.grad(b).unwrap().clone();
        let gx = tape.grad(x).unwrap();
        assert!((gb.get(0, 0) - gx.col_sum()[0]).abs() < 1e-6);
        assert!((gb.get(0, 1) - gx.col_sum()[1]).abs() < 1e-6);
    }

    #[test]
    fn mask_excludes_rows() {
        // loss over masked rows only; gradient on excluded row is zero
        let mut tape = Tape::new(1);
        let x = tape.input(Dense::from_vec(2, 2, vec![1.0, -1.0, 0.5, 0.5]).unwrap());
        let loss = tape.softmax_xent(x, &[0, 1], Some(&[true, false])).unwrap();
        tape.backward(loss).unwrap();
        let g = tape.grad(x).unwrap();
        assert!(g.get(0, 0) != 0.0);
        assert_eq!(g.get(1, 0), 0.0);
        assert_eq!(g.get(1, 1), 0.0);
    }

    #[test]
    fn scale_and_add_backward() {
        let mut tape = Tape::new(1);
        let x = tape.input(Dense::from_vec(1, 2, vec![1.0, 2.0]).unwrap());
        let y = tape.scale(x, 3.0).unwrap();
        let z = tape.add(y, x).unwrap(); // z = 4x
        let loss = tape.softmax_xent(z, &[0], None).unwrap();
        tape.backward(loss).unwrap();
        // grad through z=4x is 4× grad at z
        let gx = tape.grad(x).unwrap().clone();
        let gz = tape.grad(z).unwrap().clone();
        assert!((gx.get(0, 0) - 4.0 * gz.get(0, 0)).abs() < 1e-6);
    }

    #[test]
    fn workspace_tape_recycles_and_stays_correct() {
        use crate::kernels::KernelWorkspace;
        use std::sync::Arc;

        let a = graph(12, 65);
        let mut rng = Rng::seed_from_u64(66);
        let x0 = Dense::uniform(12, 6, 1.0, &mut rng);
        let labels: Vec<usize> = (0..12).map(|i| i % 3).collect();
        let ws = Arc::new(KernelWorkspace::new());
        let operand =
            SpmmOperand::cached(a.clone(), "ws-tape").with_workspace(Arc::clone(&ws), 77);

        let run = |with_ws: bool| {
            let mut tape = if with_ws {
                Tape::with_workspace(2, Arc::clone(&ws))
            } else {
                Tape::new(2)
            };
            let op = if with_ws {
                operand.clone()
            } else {
                SpmmOperand::cached(a.clone(), "ws-tape")
            };
            let x = tape.input(x0.clone());
            let h = tape.spmm(&op, x).unwrap();
            let loss = tape.softmax_xent(h, &labels, None).unwrap();
            tape.backward(loss).unwrap();
            tape.grad(x).unwrap().clone()
        };

        let plain = run(false);
        // several "epochs" through the pooled path: identical gradients
        for _ in 0..4 {
            let pooled = run(true);
            assert!(pooled.allclose(&plain, 0.0));
        }
        let stats = ws.stats();
        // partitions: one for A, one for Aᵀ, the rest hits
        assert_eq!(stats.partition_misses, 2);
        assert!(stats.partition_hits >= 6, "{stats:?}");
        // after the first epoch the tape's recycled buffers feed later ones
        assert!(stats.buffer_reuses > 0, "{stats:?}");
    }

    #[test]
    fn dense_ops_draw_from_workspace_pool() {
        use crate::kernels::KernelWorkspace;
        use std::sync::Arc;
        let mut rng = Rng::seed_from_u64(67);
        let x0 = Dense::uniform(6, 4, 1.0, &mut rng);
        let w0 = Dense::uniform(4, 5, 0.5, &mut rng);
        let b0 = Dense::uniform(1, 5, 0.5, &mut rng);
        let labels: Vec<usize> = (0..6).map(|i| i % 2).collect();
        let run = |ws: Option<Arc<KernelWorkspace>>| {
            let mut tape = match ws {
                Some(ws) => Tape::with_workspace(1, ws),
                None => Tape::new(1),
            };
            let x = tape.input(x0.clone());
            let w = tape.input(w0.clone());
            let b = tape.input(b0.clone());
            let h = tape.matmul(x, w).unwrap();
            let h = tape.add_bias(h, b).unwrap();
            let h = tape.relu(h).unwrap();
            let h2 = tape.add(h, h).unwrap();
            let loss = tape.softmax_xent(h2, &labels, None).unwrap();
            tape.backward(loss).unwrap();
            tape.grad(w).unwrap().clone()
        };
        let plain = run(None);
        let ws = Arc::new(KernelWorkspace::new());
        for _ in 0..3 {
            let pooled = run(Some(Arc::clone(&ws)));
            assert!(pooled.allclose(&plain, 0.0), "workspace must not change numerics");
        }
        let stats = ws.stats();
        // epoch 2+ matmul/bias/relu/add node buffers come from the pool
        assert!(stats.buffer_reuses > 0, "{stats:?}");
    }

    /// The fused node's whole contract: value AND gradients bitwise-equal
    /// to the unfused spmm → add_bias → relu chain — for cached/uncached
    /// operands, with and without a bias, serial and pooled.
    #[test]
    fn fused_spmm_relu_matches_unfused_chain_bitwise() {
        let a = graph(14, 71);
        let mut rng = Rng::seed_from_u64(72);
        let x0 = Dense::uniform(14, 6, 1.0, &mut rng).map(|v| v - 0.5);
        let b0 = Dense::uniform(1, 6, 0.5, &mut rng).map(|v| v - 0.25);
        let labels: Vec<usize> = (0..14).map(|i| i % 3).collect();

        for threads in [1usize, 3] {
            for with_bias in [true, false] {
                let run = |fused: bool| {
                    let operand = SpmmOperand::cached(a.clone(), "fused-tape");
                    let mut tape = Tape::new(threads);
                    let x = tape.input(x0.clone());
                    let b = tape.input(b0.clone());
                    let h = if fused {
                        tape.spmm_fused_relu(&operand, x, with_bias.then_some(b)).unwrap()
                    } else {
                        let agg = tape.spmm(&operand, x).unwrap();
                        let agg = if with_bias { tape.add_bias(agg, b).unwrap() } else { agg };
                        tape.relu(agg).unwrap()
                    };
                    let loss = tape.softmax_xent(h, &labels, None).unwrap();
                    tape.backward(loss).unwrap();
                    (
                        tape.value(h).clone(),
                        tape.grad(x).unwrap().clone(),
                        tape.grad(b).cloned(),
                    )
                };
                let (fv, fgx, fgb) = run(true);
                let (uv, ugx, ugb) = run(false);
                assert_eq!(fv.data, uv.data, "value t={threads} bias={with_bias}");
                assert_eq!(fgx.data, ugx.data, "dX t={threads} bias={with_bias}");
                match (with_bias, fgb, ugb) {
                    (true, Some(fb), Some(ub)) => {
                        assert_eq!(fb.data, ub.data, "dB t={threads}")
                    }
                    (false, None, None) => {}
                    other => panic!("bias grad presence diverged: {other:?}"),
                }
            }
        }
    }

    #[test]
    fn fused_spmm_relu_pooled_and_uncached_agree() {
        use crate::kernels::KernelWorkspace;
        use std::sync::Arc;
        let a = graph(10, 73);
        let mut rng = Rng::seed_from_u64(74);
        let x0 = Dense::uniform(10, 4, 1.0, &mut rng).map(|v| v - 0.5);
        let b0 = Dense::uniform(1, 4, 0.5, &mut rng);
        let labels: Vec<usize> = (0..10).map(|i| i % 2).collect();
        let ws = Arc::new(KernelWorkspace::new());
        let run = |operand: SpmmOperand, pooled: bool| {
            let mut tape = if pooled {
                Tape::with_workspace(2, Arc::clone(&ws))
            } else {
                Tape::new(2)
            };
            let x = tape.input(x0.clone());
            let b = tape.input(b0.clone());
            let h = tape.spmm_fused_relu(&operand, x, Some(b)).unwrap();
            let loss = tape.softmax_xent(h, &labels, None).unwrap();
            tape.backward(loss).unwrap();
            (tape.value(h).clone(), tape.grad(x).unwrap().clone())
        };
        let (v1, g1) = run(SpmmOperand::cached(a.clone(), "fp"), false);
        let (v2, g2) = run(SpmmOperand::uncached(a.clone(), "fp"), false);
        let pooled_op =
            SpmmOperand::cached(a.clone(), "fp").with_workspace(Arc::clone(&ws), 31);
        let (v3, g3) = run(pooled_op, true);
        assert_eq!(v1.data, v2.data);
        assert_eq!(g1.data, g2.data);
        assert_eq!(v1.data, v3.data);
        assert_eq!(g1.data, g3.data);
        assert!(ws.stats().buffer_allocs > 0);
    }

    #[test]
    fn fused_spmm_relu_validates_bias_shape() {
        let a = graph(6, 75);
        let operand = SpmmOperand::cached(a, "fb");
        let mut tape = Tape::new(1);
        let x = tape.input(Dense::zeros(6, 4));
        let wide = tape.input(Dense::zeros(1, 5)); // wrong length
        assert!(tape.spmm_fused_relu(&operand, x, Some(wide)).is_err());
        let tall = tape.input(Dense::zeros(2, 4)); // not a 1×C row
        assert!(tape.spmm_fused_relu(&operand, x, Some(tall)).is_err());
        let ok = tape.input(Dense::zeros(1, 4));
        assert!(tape.spmm_fused_relu(&operand, x, Some(ok)).is_ok());
    }

    #[test]
    fn fused_spmm_relu_on_baseline_operands() {
        // EdgeWise and Dense operands support the fused op too (aggregate
        // then epilogue) and agree with the kernel path to fp tolerance
        let a = graph(12, 76);
        let mut rng = Rng::seed_from_u64(77);
        let x0 = Dense::uniform(12, 5, 1.0, &mut rng).map(|v| v - 0.5);
        let b0 = Dense::uniform(1, 5, 0.5, &mut rng);
        let run = |operand: SpmmOperand| {
            let mut tape = Tape::new(1);
            let x = tape.input(x0.clone());
            let b = tape.input(b0.clone());
            let h = tape.spmm_fused_relu(&operand, x, Some(b)).unwrap();
            tape.value(h).clone()
        };
        let kernel = run(SpmmOperand::cached(a.clone(), "fbase"));
        let edge = run(SpmmOperand::edgewise(a.clone(), "fbase"));
        let dense = run(SpmmOperand::densified(a.clone(), "fbase"));
        assert!(edge.allclose(&kernel, 1e-5));
        assert!(dense.allclose(&kernel, 1e-5));
    }

    #[test]
    fn backward_requires_scalar() {
        let mut tape = Tape::new(1);
        let x = tape.input(Dense::zeros(2, 2));
        assert!(tape.backward(x).is_err());
    }

    #[test]
    fn label_out_of_range_rejected() {
        let mut tape = Tape::new(1);
        let x = tape.input(Dense::zeros(2, 2));
        assert!(tape.softmax_xent(x, &[0, 5], None).is_err());
    }
}
