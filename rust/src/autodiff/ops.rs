//! Operand types shared by the tape ops.

use std::sync::Arc;

use crate::dense::Dense;
use crate::error::Result;
use crate::kernels::{GraphEpoch, KernelWorkspace};
use crate::sparse::{Coo, Csr};

/// Stable in-process identity for a graph operand, derived from the
/// registry context string. The [`crate::cache::BackpropCache`] and the
/// [`KernelWorkspace`] key their per-graph entries with the same scheme,
/// so "one graph" means the same thing at every caching layer.
pub fn context_graph_id(context: &str) -> u64 {
    use std::collections::hash_map::DefaultHasher;
    use std::hash::{Hash, Hasher};
    let mut h = DefaultHasher::new();
    context.hash(&mut h);
    h.finish()
}

/// How the tape's `spmm` node executes the aggregation — this is the
/// "framework" axis of the paper's Figure 3.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SpmmImpl {
    /// iSpLib/pytorch_sparse style: CSR kernel, routed through the
    /// registry (tuned or trusted).
    Kernel,
    /// PyG message-passing style (PT2-MP): materialise a per-edge message
    /// matrix (`nnz × K`), then scatter-add into rows. Honest cost model
    /// for gather/scatter frameworks: 2× edge traffic + an O(nnz·K)
    /// temporary per call.
    EdgeWise,
    /// Vanilla dense fallback (the paper's 93×-slower "PyTorch 2 vanilla
    /// GCN" and CogDL-small-graph comparator): densify A and run GEMM.
    Dense,
}

/// A sparse matrix as seen by the tape's `spmm` node.
///
/// `transposed == Some(Aᵀ)` is the cache-enabled configuration (paper
/// §3.3): the backward pass reuses the stored transpose. `None` is the
/// uncached baseline: every backward step pays the O(nnz) counting
/// transpose again, exactly like a framework that re-derives `Aᵀ` inside
/// autograd. (The EdgeWise and Dense strategies don't need the transpose.)
#[derive(Clone)]
pub struct SpmmOperand {
    /// The (already normalised) adjacency used in the forward pass.
    pub a: Arc<Csr>,
    /// Cached transpose for the backward pass, if caching is enabled.
    pub transposed: Option<Arc<Csr>>,
    /// Registry context key (usually the dataset name) used to resolve the
    /// tuned kernel for this operand's SpMM calls.
    pub context: String,
    /// Execution strategy.
    pub impl_kind: SpmmImpl,
    /// COO view (EdgeWise only).
    pub coo: Option<Arc<Coo>>,
    /// Densified adjacency (Dense only).
    pub dense: Option<Arc<Dense>>,
    /// Graph identity used to key per-graph workspace entries (cached NNZ
    /// partitions); defaults to [`context_graph_id`] of `context`.
    pub graph_id: u64,
    /// Graph epoch this operand's matrix belongs to. 0 for static callers
    /// (training, tuning); bumped by the serving registry when a live
    /// session absorbs an edge delta, so each epoch's workspace entries
    /// stay distinct while old-epoch batches drain.
    pub epoch: u32,
    /// Shared kernel workspace (partition cache + output-buffer pool).
    /// `None` — the default for ad-hoc operands — means every SpMM call
    /// allocates and partitions from scratch.
    pub workspace: Option<Arc<KernelWorkspace>>,
    /// Shard count for this operand's SpMM calls (1 = unsharded, the
    /// default). Stamped from [`ExecutionPlan::shards`]
    /// (`crate::plan::ExecutionPlan::shards`) by the plan executors, so
    /// training, inference and serving all route through the sharded
    /// dispatch with no per-path special cases.
    pub shards: usize,
}

impl SpmmOperand {
    /// Cached kernel operand: transpose computed once, up front.
    pub fn cached(a: Csr, context: &str) -> Self {
        let t = a.transpose();
        SpmmOperand {
            a: Arc::new(a),
            transposed: Some(Arc::new(t)),
            context: context.to_string(),
            impl_kind: SpmmImpl::Kernel,
            coo: None,
            dense: None,
            graph_id: context_graph_id(context),
            epoch: 0,
            workspace: None,
            shards: 1,
        }
    }

    /// Cached operand from pre-computed parts (e.g. out of a
    /// [`BackpropCache`](crate::cache::BackpropCache)).
    pub fn from_cached_parts(a: Arc<Csr>, transposed: Arc<Csr>, context: &str) -> Self {
        SpmmOperand {
            a,
            transposed: Some(transposed),
            context: context.to_string(),
            impl_kind: SpmmImpl::Kernel,
            coo: None,
            dense: None,
            graph_id: context_graph_id(context),
            epoch: 0,
            workspace: None,
            shards: 1,
        }
    }

    /// Uncached kernel operand: backward recomputes the transpose per step.
    pub fn uncached(a: Csr, context: &str) -> Self {
        SpmmOperand {
            a: Arc::new(a),
            transposed: None,
            context: context.to_string(),
            impl_kind: SpmmImpl::Kernel,
            coo: None,
            dense: None,
            graph_id: context_graph_id(context),
            epoch: 0,
            workspace: None,
            shards: 1,
        }
    }

    /// Message-passing operand (PT2-MP baseline).
    pub fn edgewise(a: Csr, context: &str) -> Self {
        let coo = a.to_coo();
        SpmmOperand {
            a: Arc::new(a),
            transposed: None,
            context: context.to_string(),
            impl_kind: SpmmImpl::EdgeWise,
            coo: Some(Arc::new(coo)),
            dense: None,
            graph_id: context_graph_id(context),
            epoch: 0,
            workspace: None,
            shards: 1,
        }
    }

    /// Dense-fallback operand (vanilla / CogDL-small baseline).
    pub fn densified(a: Csr, context: &str) -> Self {
        let dense = a.to_dense();
        SpmmOperand {
            a: Arc::new(a),
            transposed: None,
            context: context.to_string(),
            impl_kind: SpmmImpl::Dense,
            coo: None,
            dense: Some(Arc::new(dense)),
            graph_id: context_graph_id(context),
            epoch: 0,
            workspace: None,
            shards: 1,
        }
    }

    /// Attach a shared [`KernelWorkspace`] under an explicit graph id (the
    /// trainer passes the same id it keys the
    /// [`BackpropCache`](crate::cache::BackpropCache) with). All SpMM
    /// calls issued through this operand then reuse cached partitions and
    /// pooled output buffers.
    pub fn with_workspace(mut self, workspace: Arc<KernelWorkspace>, graph_id: u64) -> Self {
        self.workspace = Some(workspace);
        self.graph_id = graph_id;
        self
    }

    /// Stamp this operand with a graph epoch (serving-registry mutation
    /// path); all workspace entries its SpMM calls touch are then keyed
    /// under `(graph_id, epoch)`.
    pub fn with_epoch(mut self, epoch: u32) -> Self {
        self.epoch = epoch;
        self
    }

    /// Stamp this operand with a shard count. `0` is normalised to `1`
    /// (unsharded); the executors call this once per plan execution with
    /// the plan's shard property.
    pub fn with_shards(mut self, shards: usize) -> Self {
        self.shards = shards.max(1);
        self
    }

    /// The workspace cache key for this operand's matrix.
    pub fn graph_key(&self) -> GraphEpoch {
        GraphEpoch::new(self.graph_id, self.epoch)
    }

    /// Get `Aᵀ` — from the cache, or recomputed (the §3.3 cost difference
    /// made explicit).
    pub fn transpose(&self) -> Arc<Csr> {
        match &self.transposed {
            Some(t) => Arc::clone(t),
            None => Arc::new(self.a.transpose()),
        }
    }

    /// Whether the operand carries a cached transpose.
    pub fn is_cached(&self) -> bool {
        self.transposed.is_some()
    }

    /// Forward aggregation for the EdgeWise strategy: materialise messages
    /// `m_e = v_e · x[col_e]`, then scatter-add into `out[row_e]`.
    pub(crate) fn edgewise_forward(&self, x: &Dense) -> Result<Dense> {
        let coo = self.coo.as_ref().expect("edgewise operand has coo");
        let k = x.cols;
        // message materialisation — the deliberate PT2-MP overhead
        let mut messages = Dense::zeros(coo.nnz(), k);
        for (e, (&c, &v)) in coo.col_idx.iter().zip(coo.values.iter()).enumerate() {
            let src = x.row(c);
            let dst = messages.row_mut(e);
            for (d, &s) in dst.iter_mut().zip(src.iter()) {
                *d = v * s;
            }
        }
        let mut out = Dense::zeros(self.a.rows, k);
        for (e, &r) in coo.row_idx.iter().enumerate() {
            let msg = messages.row(e);
            let dst = out.row_mut(r);
            for (d, &m) in dst.iter_mut().zip(msg.iter()) {
                *d += m;
            }
        }
        Ok(out)
    }

    /// Backward of the EdgeWise strategy: scatter gradients back along
    /// edges (`dX[col_e] += v_e · dY[row_e]`), again via a materialised
    /// message-gradient matrix.
    pub(crate) fn edgewise_backward(&self, dy: &Dense) -> Result<Dense> {
        let coo = self.coo.as_ref().expect("edgewise operand has coo");
        let k = dy.cols;
        let mut grad_messages = Dense::zeros(coo.nnz(), k);
        for (e, (&r, &v)) in coo.row_idx.iter().zip(coo.values.iter()).enumerate() {
            let src = dy.row(r);
            let dst = grad_messages.row_mut(e);
            for (d, &s) in dst.iter_mut().zip(src.iter()) {
                *d = v * s;
            }
        }
        let mut dx = Dense::zeros(self.a.cols, k);
        for (e, &c) in coo.col_idx.iter().enumerate() {
            let msg = grad_messages.row(e);
            let dst = dx.row_mut(c);
            for (d, &m) in dst.iter_mut().zip(msg.iter()) {
                *d += m;
            }
        }
        Ok(dx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{spmm_dense_ref, Semiring};
    use crate::sparse::Coo;

    fn toy() -> Csr {
        let mut coo = Coo::new(3, 3);
        coo.push(0, 1, 2.0);
        coo.push(2, 0, 3.0);
        coo.push(2, 1, 0.5);
        coo.to_csr()
    }

    #[test]
    fn cached_operand_stores_transpose() {
        let op = SpmmOperand::cached(toy(), "toy");
        assert!(op.is_cached());
        assert_eq!(*op.transpose(), toy().transpose());
    }

    #[test]
    fn uncached_operand_recomputes() {
        let op = SpmmOperand::uncached(toy(), "toy");
        assert!(!op.is_cached());
        assert_eq!(*op.transpose(), toy().transpose());
    }

    #[test]
    fn edgewise_forward_matches_kernel() {
        let a = toy();
        let x = Dense::from_vec(3, 2, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        let op = SpmmOperand::edgewise(a.clone(), "toy");
        let got = op.edgewise_forward(&x).unwrap();
        let want = spmm_dense_ref(&a, &x, Semiring::Sum).unwrap();
        assert!(got.allclose(&want, 1e-5));
    }

    #[test]
    fn edgewise_backward_is_transpose_spmm() {
        let a = toy();
        let dy = Dense::from_vec(3, 2, vec![0.1, 0.2, 0.3, 0.4, 0.5, 0.6]).unwrap();
        let op = SpmmOperand::edgewise(a.clone(), "toy");
        let got = op.edgewise_backward(&dy).unwrap();
        let want = spmm_dense_ref(&a.transpose(), &dy, Semiring::Sum).unwrap();
        assert!(got.allclose(&want, 1e-5));
    }

    #[test]
    fn densified_matches() {
        let a = toy();
        let op = SpmmOperand::densified(a.clone(), "toy");
        assert!(op.dense.as_ref().unwrap().allclose(&a.to_dense(), 0.0));
    }

    #[test]
    fn graph_ids_are_stable_and_context_keyed() {
        let a = toy();
        let op1 = SpmmOperand::cached(a.clone(), "ctx-a");
        let op2 = SpmmOperand::uncached(a.clone(), "ctx-a");
        let op3 = SpmmOperand::cached(a.clone(), "ctx-b");
        assert_eq!(op1.graph_id, op2.graph_id);
        assert_ne!(op1.graph_id, op3.graph_id);
        assert_eq!(op1.graph_id, context_graph_id("ctx-a"));
    }

    #[test]
    fn with_workspace_attaches() {
        use crate::kernels::KernelWorkspace;
        let ws = Arc::new(KernelWorkspace::new());
        let op = SpmmOperand::cached(toy(), "toy").with_workspace(Arc::clone(&ws), 42);
        assert_eq!(op.graph_id, 42);
        assert!(op.workspace.is_some());
    }
}
