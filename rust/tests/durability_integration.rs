//! Durable-state integration: bitwise-resumable training checkpoints,
//! and (under `--features failpoints`) the crash-recovery chaos suite.
//!
//! The acceptance story for crash-safe state:
//!
//! 1. **Bitwise resume.** Checkpointing a run at epoch `e` and resuming
//!    it in a fresh trainer ("fresh process") to epoch `N` reproduces the
//!    uninterrupted run's loss trajectory and final parameters *to the
//!    bit*, across optimizers × models × checkpoint epochs.
//! 2. **Fingerprint safety.** A checkpoint never resumes into a run with
//!    a different model, optimizer, or seed — only extending the epoch
//!    count is allowed.
//! 3. **Crash safety** (`failpoints` builds). Faults injected at every
//!    durable-write stage (`io.atomic_write` both before the temp write
//!    and before the commit rename, `io.fsync`, `train.checkpoint`) under
//!    `every_nth` and seeded-coin schedules crash the training loop
//!    mid-save — and whatever survives on disk *always* loads clean
//!    (primary or `.bak`, never torn) and resumes bitwise-identical to
//!    the uninterrupted run.
//!
//! `scripts/tier1.sh` runs this file in BOTH the default and the
//! `--features failpoints` pass: the default pass proves the
//! checkpointing machinery with failpoints compiled to no-ops, the
//! failpoints pass adds the chaos schedule on top of the same tests.

use isplib::data::{karate_club, Dataset};
use isplib::gnn::GnnModel;
use isplib::train::{Backend, OptimizerKind, TrainConfig, Trainer};
use isplib::util::tmp::TempDir;

#[cfg(feature = "failpoints")]
use isplib::util::failpoints;

/// NativeTrusted + skip_tuning: fully deterministic (no measurement on
/// the path), so bitwise equality is a meaningful assertion.
fn trainer(ds: &Dataset, model: GnnModel, opt: OptimizerKind, epochs: usize) -> Trainer {
    let cfg = TrainConfig {
        epochs,
        hidden: 8,
        optimizer: opt,
        skip_tuning: true,
        ..TrainConfig::default()
    };
    Trainer::new(model, Backend::NativeTrusted, cfg, ds).unwrap()
}

fn loss_bits(losses: &[f32]) -> Vec<u32> {
    losses.iter().map(|l| l.to_bits()).collect()
}

fn param_bits(t: &Trainer) -> Vec<(String, Vec<u32>)> {
    let params = t.export_params().unwrap();
    let mut out: Vec<(String, Vec<u32>)> = params
        .iter()
        .map(|(n, d)| (n.to_string(), d.data.iter().map(|x| x.to_bits()).collect()))
        .collect();
    out.sort();
    out
}

const SGD: OptimizerKind = OptimizerKind::Sgd { lr: 0.1, momentum: 0.0 };
const SGD_MOMENTUM: OptimizerKind = OptimizerKind::Sgd { lr: 0.05, momentum: 0.9 };
const ADAM: OptimizerKind = OptimizerKind::Adam { lr: 0.01 };

/// The headline property: for (SGD, SGD+momentum, Adam) × (GCN, GIN) ×
/// checkpoint epoch e ∈ {1, N/2, N−1}, training to e, "crashing",
/// resuming in a fresh trainer and training to N is bitwise-identical —
/// full loss trajectory AND final parameters — to the uninterrupted run.
#[test]
fn resume_is_bitwise_equal_across_optimizers_models_and_epochs() {
    // under --features failpoints the durable layer's global sites are
    // live: serialise against the chaos tests in this binary
    #[cfg(feature = "failpoints")]
    let _guard = {
        let g = failpoints::exclusive();
        failpoints::clear();
        g
    };
    let ds = karate_club();
    const EPOCHS: usize = 12;
    for opt in [SGD, SGD_MOMENTUM, ADAM] {
        for model in [GnnModel::Gcn, GnnModel::Gin] {
            let mut reference = trainer(&ds, model, opt, EPOCHS);
            let ref_report = reference.fit(&ds).unwrap();
            let ref_losses = loss_bits(&ref_report.losses);
            let ref_params = param_bits(&reference);

            for e in [1usize, EPOCHS / 2, EPOCHS - 1] {
                let dir = TempDir::new().unwrap();
                // phase 1: a run that only reaches epoch e, checkpointing
                // every epoch, then "crashes" (is dropped)
                let mut first = trainer(&ds, model, opt, e);
                first.fit_with_checkpoints(&ds, Some(dir.path()), 1).unwrap();
                assert_eq!(first.epochs_run(), e);
                drop(first);

                // phase 2: a fresh trainer resumes from disk and finishes
                let mut resumed = trainer(&ds, model, opt, EPOCHS);
                assert!(
                    resumed.resume(dir.path()).unwrap(),
                    "{model:?}/{opt:?}: checkpoint at epoch {e} must load"
                );
                assert_eq!(resumed.epochs_run(), e);
                let report = resumed.fit(&ds).unwrap();
                assert_eq!(report.losses.len(), EPOCHS);
                assert_eq!(
                    loss_bits(&report.losses),
                    ref_losses,
                    "{model:?}/{opt:?} resumed at {e}: loss trajectory diverged"
                );
                assert_eq!(
                    param_bits(&resumed),
                    ref_params,
                    "{model:?}/{opt:?} resumed at {e}: final parameters diverged"
                );
            }
        }
    }
}

/// The fingerprint gate: a checkpoint refuses to resume into any run it
/// did not come from — different model, optimizer, or seed — while a
/// same-run trainer with MORE epochs resumes fine (extension) and one
/// with FEWER epochs than the checkpoint is rejected.
#[test]
fn resume_rejects_mismatched_runs_and_allows_extension() {
    #[cfg(feature = "failpoints")]
    let _guard = {
        let g = failpoints::exclusive();
        failpoints::clear();
        g
    };
    let ds = karate_club();
    let dir = TempDir::new().unwrap();
    let mut t = trainer(&ds, GnnModel::Gcn, SGD, 3);
    t.fit_with_checkpoints(&ds, Some(dir.path()), 0).unwrap();

    // wrong model
    let err = trainer(&ds, GnnModel::Gin, SGD, 3).resume(dir.path()).unwrap_err();
    assert!(err.to_string().contains("fingerprint mismatch"), "{err}");
    // wrong optimizer
    let err = trainer(&ds, GnnModel::Gcn, ADAM, 3).resume(dir.path()).unwrap_err();
    assert!(err.to_string().contains("fingerprint mismatch"), "{err}");
    // wrong seed
    let cfg = TrainConfig {
        epochs: 3,
        hidden: 8,
        optimizer: SGD,
        seed: 7,
        skip_tuning: true,
        ..TrainConfig::default()
    };
    let mut other = Trainer::new(GnnModel::Gcn, Backend::NativeTrusted, cfg, &ds).unwrap();
    assert!(other.resume(dir.path()).unwrap_err().to_string().contains("fingerprint"));

    // a shorter run than the checkpoint cannot absorb it
    let err = trainer(&ds, GnnModel::Gcn, SGD, 1).resume(dir.path()).unwrap_err();
    assert!(err.to_string().contains("only goes to"), "{err}");

    // extension is legitimate: same run, more epochs
    let mut extended = trainer(&ds, GnnModel::Gcn, SGD, 6);
    assert!(extended.resume(dir.path()).unwrap());
    assert_eq!(extended.epochs_run(), 3);
    let report = extended.fit(&ds).unwrap();
    assert_eq!(report.losses.len(), 6);

    // an empty directory is a fresh start, not an error
    let empty = TempDir::new().unwrap();
    assert!(!trainer(&ds, GnnModel::Gcn, SGD, 3).resume(empty.path()).unwrap());
}

/// Crash-recovery chaos: kill the durable-write machinery at every stage
/// and prove no on-disk state is ever unrecoverable.
#[cfg(feature = "failpoints")]
mod chaos {
    use super::*;
    use isplib::util::failpoints::{FailAction, FailPlan};

    /// Uninterrupted reference trajectory for the chaos runs.
    fn reference(ds: &Dataset, epochs: usize) -> (Vec<u32>, Vec<(String, Vec<u32>)>) {
        let mut t = trainer(ds, GnnModel::Gcn, SGD_MOMENTUM, epochs);
        let report = t.fit(ds).unwrap();
        (loss_bits(&report.losses), param_bits(&t))
    }

    /// Crash-restart loop: keep resuming from disk and re-running until a
    /// pass completes. Each crash must leave a state that loads clean —
    /// any `CorruptState` (or panic) fails the test. Returns the number
    /// of crashes endured.
    fn crash_loop_to_completion(
        ds: &Dataset,
        dir: &std::path::Path,
        epochs: usize,
        want_losses: &[u32],
        want_params: &[(String, Vec<u32>)],
    ) -> usize {
        let mut crashes = 0;
        loop {
            let mut t = trainer(ds, GnnModel::Gcn, SGD_MOMENTUM, epochs);
            // the probe-load after a crash IS the assertion: torn state
            // would surface here as CorruptState instead of Ok
            t.resume(dir).unwrap_or_else(|e| {
                panic!("crash #{crashes} left unrecoverable state: {e}")
            });
            match t.fit_with_checkpoints(ds, Some(dir), 1) {
                Ok(report) => {
                    assert_eq!(loss_bits(&report.losses), want_losses, "chaos run diverged");
                    assert_eq!(param_bits(&t), want_params, "chaos params diverged");
                    return crashes;
                }
                Err(e) => {
                    assert!(
                        e.to_string().contains("failpoint"),
                        "only injected faults may crash the loop, got: {e}"
                    );
                    crashes += 1;
                    assert!(crashes < 64, "crash loop failed to converge");
                }
            }
        }
    }

    /// A torn temp-file write (fault at the first `io.atomic_write` stage
    /// of save #2) loses nothing: the epoch-1 checkpoint still loads and
    /// the resumed run is bitwise-identical to the uninterrupted one.
    #[test]
    fn torn_temp_write_resumes_bitwise_from_the_prior_save() {
        let _guard = failpoints::exclusive();
        failpoints::clear();
        let ds = karate_club();
        const EPOCHS: usize = 8;
        let (want_losses, want_params) = reference(&ds, EPOCHS);

        let dir = TempDir::new().unwrap();
        // each save hits io.atomic_write twice (temp stage, pre-commit):
        // hits 1–2 are save 1, hit 3 is save 2's temp stage → tear it
        failpoints::configure(
            "io.atomic_write",
            FailPlan::always(FailAction::TransientError).after(2).limit(1),
        );
        let mut t = trainer(&ds, GnnModel::Gcn, SGD_MOMENTUM, EPOCHS);
        let err = t.fit_with_checkpoints(&ds, Some(dir.path()), 1).unwrap_err();
        assert!(err.to_string().contains("io.atomic_write"), "{err}");
        assert_eq!(failpoints::fires("io.atomic_write"), 1);
        failpoints::clear();

        let mut resumed = trainer(&ds, GnnModel::Gcn, SGD_MOMENTUM, EPOCHS);
        assert!(resumed.resume(dir.path()).unwrap(), "save 1 must have survived");
        assert_eq!(resumed.epochs_run(), 1);
        let report = resumed.fit(&ds).unwrap();
        assert_eq!(loss_bits(&report.losses), want_losses);
        assert_eq!(param_bits(&resumed), want_params);
        failpoints::clear();
    }

    /// Power loss at fsync (temp file written but never synced): the
    /// previous checkpoint generation stays loadable and resume is clean.
    #[test]
    fn fsync_fault_falls_back_to_the_previous_generation() {
        let _guard = failpoints::exclusive();
        failpoints::clear();
        let ds = karate_club();
        const EPOCHS: usize = 6;
        let (want_losses, want_params) = reference(&ds, EPOCHS);

        let dir = TempDir::new().unwrap();
        // one io.fsync hit per save: let save 1 through, kill save 2
        failpoints::configure(
            "io.fsync",
            FailPlan::always(FailAction::TransientError).after(1).limit(1),
        );
        let mut t = trainer(&ds, GnnModel::Gcn, SGD_MOMENTUM, EPOCHS);
        let err = t.fit_with_checkpoints(&ds, Some(dir.path()), 1).unwrap_err();
        assert!(err.to_string().contains("io.fsync"), "{err}");
        failpoints::clear();

        let mut resumed = trainer(&ds, GnnModel::Gcn, SGD_MOMENTUM, EPOCHS);
        assert!(resumed.resume(dir.path()).unwrap());
        assert_eq!(resumed.epochs_run(), 1);
        let report = resumed.fit(&ds).unwrap();
        assert_eq!(loss_bits(&report.losses), want_losses);
        assert_eq!(param_bits(&resumed), want_params);
        failpoints::clear();
    }

    /// `every_nth` schedule: every 5th durable-write stage errors,
    /// repeatedly crashing the run mid-training. The crash-restart loop
    /// resumes from disk each time and still converges to the
    /// uninterrupted trajectory, bit for bit.
    #[test]
    fn every_nth_fault_schedule_crash_loops_to_a_bitwise_clean_finish() {
        let _guard = failpoints::exclusive();
        failpoints::clear();
        let ds = karate_club();
        const EPOCHS: usize = 10;
        let (want_losses, want_params) = reference(&ds, EPOCHS);

        let dir = TempDir::new().unwrap();
        // 2 hits per save → roughly every 3rd save dies, at alternating
        // stages (temp tear / pre-commit, exercising the .bak fallback);
        // bounded so the loop terminates
        failpoints::configure(
            "io.atomic_write",
            FailPlan::always(FailAction::TransientError).every_nth(5).limit(4),
        );
        let crashes =
            crash_loop_to_completion(&ds, dir.path(), EPOCHS, &want_losses, &want_params);
        assert!(crashes >= 1, "the schedule must have crashed at least one pass");
        failpoints::clear();
    }

    /// Seeded-coin schedule across BOTH io sites at once: random saves die
    /// at random stages, and every intermediate on-disk state still loads
    /// clean until the run completes bitwise-identical.
    #[test]
    fn probabilistic_fault_schedule_never_leaves_torn_state() {
        let _guard = failpoints::exclusive();
        failpoints::clear();
        let ds = karate_club();
        const EPOCHS: usize = 10;
        let (want_losses, want_params) = reference(&ds, EPOCHS);

        let dir = TempDir::new().unwrap();
        failpoints::configure(
            "io.atomic_write",
            FailPlan::always(FailAction::TransientError).with_probability(0.35, 2024).limit(4),
        );
        failpoints::configure(
            "io.fsync",
            FailPlan::always(FailAction::TransientError).with_probability(0.35, 4202).limit(3),
        );
        let crashes =
            crash_loop_to_completion(&ds, dir.path(), EPOCHS, &want_losses, &want_params);
        // p=0.35 over ≥30 stage hits: astronomically unlikely to never fire
        assert!(crashes >= 1, "the coin never fired — schedule not exercised");
        failpoints::clear();
    }

    /// The `train.checkpoint` site fires BEFORE any disk write: an
    /// injected fault there aborts the save without touching the
    /// directory at all.
    #[test]
    fn train_checkpoint_fault_aborts_before_touching_disk() {
        let _guard = failpoints::exclusive();
        failpoints::clear();
        let ds = karate_club();
        let dir = TempDir::new().unwrap();
        failpoints::configure(
            "train.checkpoint",
            FailPlan::always(FailAction::TransientError).with_tag("gcn").limit(1),
        );
        let mut t = trainer(&ds, GnnModel::Gcn, SGD, 4);
        let err = t.fit_with_checkpoints(&ds, Some(dir.path()), 1).unwrap_err();
        assert!(err.to_string().contains("train.checkpoint"), "{err}");
        assert!(
            !isplib::train::TrainCheckpoint::path(dir.path()).exists(),
            "the fault fired before the save began — nothing may be on disk"
        );
        // a fresh start resumes nothing and trains through cleanly
        let mut t = trainer(&ds, GnnModel::Gcn, SGD, 4);
        assert!(!t.resume(dir.path()).unwrap());
        t.fit_with_checkpoints(&ds, Some(dir.path()), 1).unwrap();
        assert!(isplib::train::TrainCheckpoint::path(dir.path()).exists());
        failpoints::clear();
    }
}
