//! Live-mutation integration: epoch-versioned graph deltas and atomic
//! model hot-swaps against the full serving stack.
//!
//! The acceptance story for dynamic-graph serving: a session can be
//! mutated — edges inserted/deleted, model parameters hot-swapped —
//! while requests are queued and flowing, and **every** completed
//! request is bitwise-equal to the sequential reference at its
//! admission-time `(epoch, model_version)` stamp. The property test
//! drives random interleavings of submits, deltas, swaps, and partial
//! drains through the seeded [`forall`] harness (replayable with
//! `ISPLIB_CHECK_SEED`); the chaos module (behind `--features
//! failpoints`) injects faults into the mutation commit paths and
//! asserts the old epoch/model keeps serving bit-for-bit.

use std::collections::{BTreeSet, HashMap};
use std::time::Duration;

use isplib::autotune::{DbEntry, HardwareProfile, TuneConfig, Tuner, TuningDb};
use isplib::dense::Dense;
use isplib::gnn::{GnnModel, ModelParams};
use isplib::serve::{EdgeDelta, InferenceServer, ServeConfig};
use isplib::sparse::{Coo, Csr};
use isplib::util::check::{default_cases, forall};
use isplib::util::rng::Rng;

/// A symmetric ring over `n` nodes: every row keeps at least its two ring
/// edges however many inserted edges a test later deletes, so GCN
/// normalisation never meets an empty row.
fn ring_graph(n: usize) -> (Csr, BTreeSet<(usize, usize)>) {
    let mut coo = Coo::new(n, n);
    let mut edges = BTreeSet::new();
    for i in 0..n {
        let j = (i + 1) % n;
        coo.push_sym(i, j, 1.0);
        edges.insert((i, j));
        edges.insert((j, i));
    }
    (coo.to_csr(), edges)
}

fn dims() -> ModelParams {
    ModelParams { in_dim: 4, hidden: 8, classes: 3 }
}

/// Build a random valid delta against the mirrored edge set: inserts at
/// fresh or existing (upsert) positions, deletes only among edges a
/// previous delta inserted (ring edges stay, keeping rows non-empty).
/// Updates the mirrors to match what the server will hold after commit.
fn random_delta(
    n: usize,
    edges: &mut BTreeSet<(usize, usize)>,
    inserted: &mut Vec<(usize, usize)>,
    rng: &mut Rng,
) -> EdgeDelta {
    let mut delta = EdgeDelta::new();
    let mut touched: BTreeSet<(usize, usize)> = BTreeSet::new();
    for _ in 0..(1 + rng.gen_range(3)) {
        if !inserted.is_empty() && rng.gen_bool(0.3) {
            let k = rng.gen_range(inserted.len());
            let (r, c) = inserted[k];
            if touched.insert((r, c)) {
                inserted.swap_remove(k);
                edges.remove(&(r, c));
                delta = delta.del(r, c);
            }
        } else {
            let (r, c) = (rng.gen_range(n), rng.gen_range(n));
            if touched.insert((r, c)) {
                if edges.insert((r, c)) {
                    inserted.push((r, c));
                }
                delta = delta.add(r, c, rng.gen_range_f32(0.1, 1.0));
            }
        }
    }
    delta
}

/// The tentpole property: over random interleavings of submits, edge
/// deltas, model swaps, and partial drains, every completion is
/// bitwise-equal to the [`InferenceServer::infer_at`] reference taken at
/// its admission stamp — and once nothing is in flight, exactly one
/// epoch and one param version remain live.
#[test]
fn random_interleavings_serve_every_request_at_its_admission_stamp() {
    forall("serve_mutation_interleaving", default_cases(), |rng| {
        let n = 8 + rng.gen_range(9);
        let (adj, mut edges) = ring_graph(n);
        let mut inserted: Vec<(usize, usize)> = Vec::new();
        let cfg = ServeConfig {
            max_batch: 1 + rng.gen_range(4),
            quantum: 8,
            threads: 1,
            max_wait: Duration::ZERO,
            // flip between always-refresh and the default carry-leaning
            // policy: correctness must not depend on the tuning decision
            staleness: if rng.gen_bool(0.5) { 0.0 } else { 0.25 },
            ..ServeConfig::default()
        };
        let mut server = InferenceServer::new(cfg);
        let d = dims();
        let sid = server
            .register_session(
                "mutate-prop",
                GnnModel::Gcn,
                d,
                GnnModel::Gcn.init_params(d, 7),
                &adj,
                None,
            )
            .unwrap();

        let mut expect: HashMap<u64, Vec<f32>> = HashMap::new();
        let mut completed = Vec::new();
        for _ in 0..24 {
            match rng.gen_range(8) {
                0..=3 => {
                    let x = Dense::uniform(n, d.in_dim, 1.0, rng);
                    let rid = server.submit(sid, x.clone()).unwrap();
                    let s = server.session(sid).unwrap();
                    let (e, v) = (s.epoch(), s.model_version());
                    expect.insert(rid, server.infer_at(sid, e, v, &x).unwrap().data);
                }
                4 | 5 => {
                    let delta = random_delta(n, &mut edges, &mut inserted, rng);
                    let before = server.session(sid).unwrap().epoch();
                    let out = server.apply_delta(sid, &delta, None).unwrap();
                    assert_eq!(out.epoch, before + 1);
                }
                6 => {
                    let seed = rng.next_u64();
                    server.swap_model(sid, GnnModel::Gcn.init_params(d, seed)).unwrap();
                }
                _ => completed.extend(server.run_ready().unwrap()),
            }
        }
        completed.extend(server.run_until_drained().unwrap());

        assert_eq!(completed.len(), expect.len(), "every request terminates exactly once");
        for c in &completed {
            assert_eq!(
                c.expect_output().data, expect[&c.id],
                "request {} diverged from its admission-stamp reference",
                c.id
            );
        }
        // quiescent: every superseded epoch/version has retired
        let s = server.session(sid).unwrap();
        assert_eq!(s.live_epochs(), 1, "old epochs must retire once nothing is in flight");
        assert_eq!(s.live_param_versions(), 1);
    });
}

/// Mutating one tenant never perturbs a co-tenant sharing the scheduler
/// and workspace: the bystander's completions stay bitwise-equal to its
/// own pre-churn references throughout.
#[test]
fn mutations_on_one_tenant_leave_the_co_tenant_bitwise_clean() {
    let mut server = InferenceServer::new(ServeConfig {
        max_batch: 4,
        quantum: 4,
        threads: 1,
        max_wait: Duration::ZERO,
        ..ServeConfig::default()
    });
    let d = dims();
    let (adj_a, mut edges) = ring_graph(16);
    let (adj_b, _) = ring_graph(12);
    let mut inserted = Vec::new();
    let churn = server
        .register_session("mut-churner", GnnModel::Gcn, d, GnnModel::Gcn.init_params(d, 1), &adj_a, None)
        .unwrap();
    let stay = server
        .register_session("mut-bystander", GnnModel::Gcn, d, GnnModel::Gcn.init_params(d, 2), &adj_b, None)
        .unwrap();
    let mut rng = Rng::seed_from_u64(55);
    let mut expect: HashMap<u64, Vec<f32>> = HashMap::new();
    let mut completed = Vec::new();
    for round in 0..6 {
        for _ in 0..2 {
            let x = Dense::uniform(16, d.in_dim, 1.0, &mut rng);
            let rid = server.submit(churn, x.clone()).unwrap();
            let s = server.session(churn).unwrap();
            let (e, v) = (s.epoch(), s.model_version());
            expect.insert(rid, server.infer_at(churn, e, v, &x).unwrap().data);
            let xb = Dense::uniform(12, d.in_dim, 1.0, &mut rng);
            let rid = server.submit(stay, xb.clone()).unwrap();
            expect.insert(rid, server.infer_at(stay, 0, 0, &xb).unwrap().data);
        }
        if round % 2 == 0 {
            let delta = random_delta(16, &mut edges, &mut inserted, &mut rng);
            server.apply_delta(churn, &delta, None).unwrap();
        } else {
            server.swap_model(churn, GnnModel::Gcn.init_params(d, 100 + round)).unwrap();
        }
        completed.extend(server.run_ready().unwrap());
    }
    completed.extend(server.run_until_drained().unwrap());
    assert_eq!(completed.len(), expect.len());
    for c in &completed {
        assert_eq!(c.expect_output().data, expect[&c.id], "request {}", c.id);
    }
    // the bystander never moved off its registration stamp
    let s = server.session(stay).unwrap();
    assert_eq!((s.epoch(), s.model_version()), (0, 0));
    assert_eq!(server.metrics(stay).unwrap().deltas_applied, 0);
    // the churner accumulated its mutations
    let s = server.session(churn).unwrap();
    assert_eq!(s.epoch(), 3);
    assert_eq!(s.model_version(), 3);
}

/// A warm-started session keeps its zero-conversion hot path across
/// deltas: below the staleness threshold the tuned format carries over
/// (re-materialised for the new epoch off the request path), the retired
/// epoch's conversion leaves the workspace, and serving stays
/// bitwise-equal to the reference.
#[test]
fn tuned_formats_follow_epochs_under_churn() {
    let name = "mutate-warm";
    let tuner = Tuner::with_config(HardwareProfile::amd_epyc(), TuneConfig::quick());
    let mut db = TuningDb::default();
    db.put(
        name,
        "amd-epyc",
        8,
        DbEntry { sell: Some((4, 32)), speedup: 1.5, ..DbEntry::default() },
    );
    let mut server = InferenceServer::new(ServeConfig {
        max_batch: 1,
        quantum: 4,
        threads: 1,
        max_wait: Duration::ZERO,
        staleness: 1e9, // never refresh: the carry path is under test
        ..ServeConfig::default()
    });
    let d = dims();
    let (adj, _) = ring_graph(48);
    let sid = server
        .register_session(
            name,
            GnnModel::Gcn,
            d,
            GnnModel::Gcn.init_params(d, 5),
            &adj,
            Some((&tuner, &db)),
        )
        .unwrap();
    assert_eq!(server.workspace().cached_formats(), 1, "warm start converted one format");

    let mut rng = Rng::seed_from_u64(66);
    let x = Dense::uniform(48, d.in_dim, 1.0, &mut rng);
    server.submit(sid, x.clone()).unwrap();
    let done = server.run_until_drained().unwrap();
    assert_eq!(done[0].expect_output().data, server.infer_now(sid, &x).unwrap().data);

    let out = server
        .apply_delta(sid, &EdgeDelta::new().add(0, 24, 0.5).add(24, 0, 0.5), Some((&tuner, &db)))
        .unwrap();
    assert!(!out.refreshed, "drift {} must stay under the 1e9 threshold", out.drift);
    assert_eq!(
        server.workspace().cached_formats(),
        1,
        "epoch 0's conversion retired with it; epoch 1 carries exactly one"
    );
    // the carried format still serves the new structure bitwise-correctly
    server.submit(sid, x.clone()).unwrap();
    let done = server.run_until_drained().unwrap();
    assert_eq!(done[0].expect_output().data, server.infer_now(sid, &x).unwrap().data);
    // close releases the lot
    server.close_session(sid).unwrap();
    assert_eq!(server.workspace().cached_formats(), 0);
}

/// Shard-sliced workspace state is epoch-keyed like every other cached
/// conversion: a session whose tuning DB carries a shard decision serves
/// shard-lowered, its shard plans cache under the live `(graph, epoch)`
/// key, retire with that epoch when a delta commits, and the new epoch
/// rebuilds exactly its own — with serving bitwise-equal throughout.
#[test]
fn shard_plans_follow_epochs_under_churn() {
    let name = "mutate-sharded";
    let tuner = Tuner::with_config(HardwareProfile::amd_epyc(), TuneConfig::quick());
    let d = dims();
    // the shard axis keys on the widest coalesced SpMM width
    let widest = *GnnModel::Gcn
        .lower(d, GnnModel::Gcn.norm_kind())
        .spmm_shapes_batched(1)
        .last()
        .unwrap();
    let mut db = TuningDb::default();
    db.put(
        name,
        "amd-epyc",
        widest,
        DbEntry { speedup: 1.2, shards: Some(2), ..DbEntry::default() },
    );
    let mut server = InferenceServer::new(ServeConfig {
        max_batch: 1,
        quantum: 4,
        threads: 1,
        max_wait: Duration::ZERO,
        staleness: 1e9, // carry path: the shard lowering must survive a non-refreshing delta
        ..ServeConfig::default()
    });
    let (adj, _) = ring_graph(48);
    let sid = server
        .register_session(
            name,
            GnnModel::Gcn,
            d,
            GnnModel::Gcn.init_params(d, 9),
            &adj,
            Some((&tuner, &db)),
        )
        .unwrap();
    assert_eq!(server.session(sid).unwrap().plan().shards(), 2, "warm start shard-lowers the plan");
    assert_eq!(server.workspace().cached_shard_plans(), 0, "shard plans build lazily");

    let mut rng = Rng::seed_from_u64(91);
    let x = Dense::uniform(48, d.in_dim, 1.0, &mut rng);
    server.submit(sid, x.clone()).unwrap();
    let done = server.run_until_drained().unwrap();
    assert_eq!(done[0].expect_output().data, server.infer_now(sid, &x).unwrap().data);
    let epoch0 = server.workspace().cached_shard_plans();
    assert!(epoch0 > 0, "sharded serving caches its shard plans");

    let out = server
        .apply_delta(sid, &EdgeDelta::new().add(0, 24, 0.5).add(24, 0, 0.5), Some((&tuner, &db)))
        .unwrap();
    assert!(!out.refreshed, "drift {} must stay under the 1e9 threshold", out.drift);
    assert_eq!(server.session(sid).unwrap().plan().shards(), 2, "carry keeps the shard lowering");

    server.submit(sid, x.clone()).unwrap();
    let done = server.run_until_drained().unwrap();
    assert_eq!(done[0].expect_output().data, server.infer_now(sid, &x).unwrap().data);
    assert_eq!(
        server.workspace().cached_shard_plans(),
        epoch0,
        "epoch 0's shard plans retired with it; epoch 1 rebuilt exactly its own"
    );

    // close releases the lot
    server.close_session(sid).unwrap();
    assert_eq!(server.workspace().cached_shard_plans(), 0);
}

/// Fault injection against the mutation commit paths (`--features
/// failpoints`): a fault mid-delta or mid-swap must leave the old
/// epoch/model serving bit-for-bit, including work already queued, and
/// the whole schedule must reproduce exactly from fixed seeds.
#[cfg(feature = "failpoints")]
mod chaos {
    use super::*;
    use isplib::error::Error;
    use isplib::util::failpoints::{self, FailAction, FailPlan};

    #[test]
    fn fault_during_delta_with_queued_work_keeps_old_epoch_serving() {
        let _guard = failpoints::exclusive();
        failpoints::clear();
        let name = "mut-chaos-delta";
        let mut server = InferenceServer::new(ServeConfig {
            max_batch: 4,
            quantum: 4,
            threads: 1,
            ..ServeConfig::default()
        });
        let d = dims();
        let (adj, _) = ring_graph(12);
        let sid = server
            .register_session(name, GnnModel::Gcn, d, GnnModel::Gcn.init_params(d, 3), &adj, None)
            .unwrap();
        let mut rng = Rng::seed_from_u64(77);
        let mut expect = HashMap::new();
        for _ in 0..3 {
            let x = Dense::uniform(12, d.in_dim, 1.0, &mut rng);
            let rid = server.submit(sid, x.clone()).unwrap();
            expect.insert(rid, server.infer_at(sid, 0, 0, &x).unwrap().data);
        }

        failpoints::configure(
            "serve.apply_delta",
            FailPlan::always(FailAction::Panic).with_tag(name).limit(1),
        );
        let delta = EdgeDelta::new().add(0, 6, 0.5).add(6, 0, 0.5);
        let err = server.apply_delta(sid, &delta, None).unwrap_err();
        assert!(matches!(err, Error::RequestFailed(_)), "{err}");
        assert_eq!(server.session(sid).unwrap().epoch(), 0, "failed delta is a no-op");

        // the queued cohort drains bitwise-clean off the untouched epoch
        let done = server.run_until_drained().unwrap();
        assert_eq!(done.len(), 3);
        for c in &done {
            assert_eq!(c.expect_output().data, expect[&c.id], "request {}", c.id);
        }
        failpoints::clear();
    }

    #[test]
    fn seeded_mutation_fault_schedule_is_deterministic_and_bitwise_clean() {
        let _guard = failpoints::exclusive();
        let name = "mut-chaos-seeded";

        // one full churn run under a probabilistic fault schedule; returns
        // every terminal observation so two runs can be compared byte for
        // byte
        let run = || -> (Vec<(u64, Vec<f32>)>, u32, u32, u64, u64, u64) {
            failpoints::clear();
            failpoints::configure(
                "serve.apply_delta",
                FailPlan::always(FailAction::Panic).with_tag(name).with_probability(0.5, 7),
            );
            failpoints::configure(
                "serve.hot_swap",
                FailPlan::always(FailAction::TransientError)
                    .with_tag(name)
                    .with_probability(0.5, 9),
            );
            let mut server = InferenceServer::new(ServeConfig {
                max_batch: 4,
                quantum: 8,
                threads: 1,
                max_wait: Duration::ZERO,
                ..ServeConfig::default()
            });
            let d = dims();
            let n = 12;
            let (adj, mut edges) = ring_graph(n);
            let mut inserted = Vec::new();
            let sid = server
                .register_session(name, GnnModel::Gcn, d, GnnModel::Gcn.init_params(d, 4), &adj, None)
                .unwrap();
            let mut rng = Rng::seed_from_u64(88);
            let mut expect: HashMap<u64, Vec<f32>> = HashMap::new();
            let mut served: Vec<(u64, Vec<f32>)> = Vec::new();
            let mut observed = Vec::new();
            for step in 0..18 {
                match step % 4 {
                    0 | 2 => {
                        let x = Dense::uniform(n, d.in_dim, 1.0, &mut rng);
                        let rid = server.submit(sid, x.clone()).unwrap();
                        let s = server.session(sid).unwrap();
                        let (e, v) = (s.epoch(), s.model_version());
                        expect.insert(rid, server.infer_at(sid, e, v, &x).unwrap().data);
                    }
                    1 => {
                        // mirror the server state only when the delta lands
                        let mut e2 = edges.clone();
                        let mut i2 = inserted.clone();
                        let delta = random_delta(n, &mut e2, &mut i2, &mut rng);
                        if server.apply_delta(sid, &delta, None).is_ok() {
                            edges = e2;
                            inserted = i2;
                        }
                    }
                    _ => {
                        let seed = rng.next_u64();
                        let _ = server.swap_model(sid, GnnModel::Gcn.init_params(d, seed));
                    }
                }
                observed.extend(server.run_ready().unwrap());
            }
            observed.extend(server.run_until_drained().unwrap());
            // under mutation faults every REQUEST still succeeds bitwise —
            // faults target the commit paths, not batch execution
            for c in &observed {
                let out = c.expect_output();
                assert_eq!(out.data, expect[&c.id], "request {}", c.id);
                served.push((c.id, out.data.clone()));
            }
            served.sort_by_key(|(id, _)| *id);
            let s = server.session(sid).unwrap();
            let m = server.metrics(sid).unwrap();
            let summary = (
                served,
                s.epoch(),
                s.model_version(),
                m.deltas_applied,
                m.swaps,
                m.swaps_rejected,
            );
            failpoints::clear();
            summary
        };

        let first = run();
        let second = run();
        assert_eq!(first, second, "the fault schedule must reproduce exactly from its seeds");
        let (_, epoch, version, deltas, swaps, swaps_rejected) = first;
        // whatever the coin decided, the books must balance: every
        // committed mutation bumped its stamp exactly once, and every
        // swap attempt terminated typed (committed or rejected)
        assert_eq!(epoch as u64, deltas, "every committed delta bumped the epoch once");
        assert_eq!(version as u64, swaps, "every committed swap bumped the version once");
        assert_eq!(swaps + swaps_rejected, 4, "all four swap attempts terminated typed");
        assert!(deltas <= 5, "five delta attempts at most");
    }
}
