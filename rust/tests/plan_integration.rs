//! ExecutionPlan integration: the one-lowering-point guarantee.
//!
//! The pre-refactor repo had three hand-written forwards kept consistent
//! by convention; these tests pin the replacement's load-bearing claims:
//!
//! * `execute_taped` and `execute_inference` are **bitwise-equal** to each
//!   other and to a hand-written oracle (the deleted per-model forward,
//!   preserved here as the reference) for all four models × sparse format
//!   {CSR, SELL-C-σ, sorted CSR} × {unfused, fused epilogue} ×
//!   serial/pooled execution — the format-routed fused kernels included.
//! * Gradients through the tape are bitwise-identical across every such
//!   configuration.
//! * The `Spmm→Relu` fusion pass changes **nothing** numerically — values
//!   and gradients — across every kernel family, and is exercised
//!   end-to-end through the serving scheduler with a warm-started fused
//!   session.

use std::collections::BTreeMap;
use std::sync::Arc;

use isplib::autodiff::{context_graph_id, SpmmOperand, Tape};
use isplib::autotune::{
    DbEntry, HardwareProfile, KernelRegistry, RegistryEntry, TuneConfig, Tuner, TuningDb,
};
use isplib::data::karate_club;
use isplib::dense::Dense;
use isplib::gnn::{GnnModel, ModelParams, ParamSet};
use isplib::kernels::{spmm, KernelChoice, KernelWorkspace, Semiring};
use isplib::plan::{execute_inference, execute_taped, ExecutionPlan};
use isplib::serve::{InferenceServer, ServeConfig};
use isplib::sparse::Csr;
use isplib::util::rng::Rng;

const HIDDEN: usize = 24;

fn setup(model: GnnModel) -> (ExecutionPlan, Csr, ParamSet, ModelParams, Dense) {
    let ds = karate_club();
    let dims = ModelParams { in_dim: ds.feature_dim(), hidden: HIDDEN, classes: ds.num_classes };
    let plan = model.lower(dims, model.norm_kind());
    let params = model.init_params(dims, 11);
    let a = model.norm_kind().apply(&ds.adj).unwrap();
    let mut rng = Rng::seed_from_u64(13);
    let x = Dense::uniform(a.rows, dims.in_dim, 1.0, &mut rng).map(|v| v - 0.5);
    (plan, a, params, dims, x)
}

/// The pre-refactor forward, preserved verbatim as the oracle: straight-
/// line per-model dataflow over the trusted serial kernel and fresh dense
/// ops. Every plan-driven execution must reproduce this bitwise.
fn oracle_forward(model: GnnModel, a: &Csr, params: &ParamSet, x: &Dense) -> Dense {
    let sp = |m: &Dense| spmm(a, m, Semiring::Sum, KernelChoice::Trusted, 1).unwrap();
    let p = |name: &str| params.get(name).unwrap();
    match model {
        GnnModel::Gcn => {
            let xw = x.matmul(p("w0")).unwrap();
            let agg = sp(&xw);
            let h = agg.add_row_broadcast(&p("b0").data).unwrap().relu();
            let hw = h.matmul(p("w1")).unwrap();
            let agg = sp(&hw);
            agg.add_row_broadcast(&p("b1").data).unwrap()
        }
        GnnModel::SageSum | GnnModel::SageMean => {
            let neigh = sp(x).matmul(p("w0_neigh")).unwrap();
            let selfp = x.matmul(p("w0_self")).unwrap();
            let h = selfp.add(&neigh).unwrap();
            let h = h.add_row_broadcast(&p("b0").data).unwrap().relu();
            let neigh = sp(&h).matmul(p("w1_neigh")).unwrap();
            let selfp = h.matmul(p("w1_self")).unwrap();
            let out = selfp.add(&neigh).unwrap();
            out.add_row_broadcast(&p("b1").data).unwrap()
        }
        GnnModel::Gin => {
            let z = x.add(&sp(x)).unwrap();
            let h = z.matmul(p("w0a")).unwrap();
            let h = h.add_row_broadcast(&p("b0a").data).unwrap().relu();
            let h = h.matmul(p("w0b")).unwrap();
            let h = h.add_row_broadcast(&p("b0b").data).unwrap().relu();
            let agg = sp(&h);
            let z = h.add(&agg).unwrap();
            let out = z.matmul(p("w1")).unwrap();
            out.add_row_broadcast(&p("b1").data).unwrap()
        }
    }
}

/// Bind `choice` for every SpMM width of `plan` (forward and, by `dX =
/// spmm(Aᵀ, dY)` symmetry, backward) under `context`, and engage routing.
fn bind_choice(context: &str, plan: &ExecutionPlan, choice: KernelChoice) {
    let registry = KernelRegistry::global();
    registry.set_patched(true);
    for k in plan.spmm_shapes() {
        registry.bind(context, k, Semiring::Sum, RegistryEntry { choice, speedup: 1.0 });
    }
}

/// Run the taped executor; returns (logits, per-param grads sorted by name).
fn run_taped(
    plan: &ExecutionPlan,
    operand: &SpmmOperand,
    params: &ParamSet,
    x: &Dense,
    threads: usize,
    ws: Option<Arc<KernelWorkspace>>,
) -> (Dense, BTreeMap<String, Dense>) {
    let mut tape = match ws {
        Some(ws) => Tape::with_workspace(threads, ws),
        None => Tape::new(threads),
    };
    let xv = tape.input(x.clone());
    let mut vars = BTreeMap::new();
    for (name, value) in params.iter() {
        vars.insert(name.clone(), tape.input(value.clone()));
    }
    let logits = execute_taped(plan, &mut tape, operand, xv, &vars).unwrap();
    let labels: Vec<usize> = (0..x.rows).map(|i| i % plan.dims().classes).collect();
    let loss = tape.softmax_xent(logits, &labels, None).unwrap();
    tape.backward(loss).unwrap();
    let value = tape.value(logits).clone();
    let grads = vars
        .iter()
        .map(|(name, var)| (name.clone(), tape.grad(*var).unwrap().clone()))
        .collect();
    (value, grads)
}

/// The satellite matrix: all four models × {CSR, SELL, sorted CSR} ×
/// {unfused, fused} × serial/pooled — taped and inference executors
/// bitwise-equal to each other, to the oracle, and (gradients) to the
/// trusted-serial reference. The fused column exercises the format-routed
/// fused epilogue kernels end-to-end: a SELL- or sorted-CSR-bound context
/// runs the format-native fused body, and must change nothing.
#[test]
fn executors_bitwise_equal_across_models_formats_fusion_and_threading() {
    let formats = [
        ("csr", KernelChoice::Trusted),
        ("sell", KernelChoice::Sell { c: 4, sigma: 32 }),
        ("sorted", KernelChoice::SortedCsr),
    ];
    for model in GnnModel::ALL {
        let (plan, a, params, _, x) = setup(model);
        let fused_plan = plan.fuse_spmm_relu(|_| true);
        let want = oracle_forward(model, &a, &params, &x);
        // the gradient reference: trusted kernel, serial, unpooled, unfused
        let ref_ctx = format!("plan-matrix-ref-{}", model.name());
        bind_choice(&ref_ctx, &plan, KernelChoice::Trusted);
        let ref_operand = SpmmOperand::cached(a.clone(), &ref_ctx);
        let (ref_logits, ref_grads) = run_taped(&plan, &ref_operand, &params, &x, 1, None);
        assert_eq!(ref_logits.data, want.data, "{model:?}: tape diverged from oracle");

        for (fname, choice) in formats {
            for fused in [false, true] {
                let exec_plan = if fused { &fused_plan } else { &plan };
                for threads in [1usize, 3] {
                    for pooled in [false, true] {
                        let label =
                            format!("{model:?}/{fname}/fused={fused}/t{threads}/pooled={pooled}");
                        let ctx = format!(
                            "plan-matrix-{}-{fname}-{fused}-{threads}-{pooled}",
                            model.name()
                        );
                        bind_choice(&ctx, &plan, choice);
                        let ws = pooled.then(|| Arc::new(KernelWorkspace::new()));
                        let mut operand = SpmmOperand::cached(a.clone(), &ctx);
                        if let Some(ws) = &ws {
                            operand =
                                operand.with_workspace(Arc::clone(ws), context_graph_id(&ctx));
                        }
                        // tape-recording executor
                        let (logits, grads) =
                            run_taped(exec_plan, &operand, &params, &x, threads, ws.clone());
                        assert_eq!(logits.data, want.data, "{label}: taped value");
                        assert_eq!(grads.len(), ref_grads.len(), "{label}");
                        for (name, g) in &grads {
                            assert_eq!(
                                g.data, ref_grads[name].data,
                                "{label}: grad '{name}' diverged"
                            );
                        }
                        // tape-free executor, solo and coalesced
                        let solo = execute_inference(exec_plan, &operand, &params, &[&x], threads)
                            .unwrap();
                        assert_eq!(solo[0].data, want.data, "{label}: inference value");
                        let batch =
                            execute_inference(exec_plan, &operand, &params, &[&x, &x, &x], threads)
                                .unwrap();
                        for out in &batch {
                            assert_eq!(out.data, want.data, "{label}: coalesced inference");
                        }
                        KernelRegistry::global().unbind_context(&ctx);
                    }
                }
            }
        }
        KernelRegistry::global().unbind_context(&ref_ctx);
    }
}

/// Fusion invariance across every kernel family: fused and unfused plans
/// produce bitwise-identical values AND gradients however the unfused
/// SpMM is routed.
#[test]
fn fusion_is_bitwise_invariant_across_kernel_families() {
    let (plan, a, params, _, x) = setup(GnnModel::Gcn);
    let fused = plan.fuse_spmm_relu(|_| true);
    assert_eq!(fused.fused_op_count(), 1);
    let families = [
        ("trusted", KernelChoice::Trusted),
        ("generated", KernelChoice::Generated { kb: 8 }),
        ("tiled", KernelChoice::Tiled { kt: 16 }),
        ("sell", KernelChoice::Sell { c: 8, sigma: 64 }),
        ("sorted", KernelChoice::SortedCsr),
    ];
    for (fname, choice) in families {
        for threads in [1usize, 3] {
            let ctx = format!("plan-fuse-{fname}-{threads}");
            bind_choice(&ctx, &plan, choice);
            let operand = SpmmOperand::cached(a.clone(), &ctx);
            let (unfused_logits, unfused_grads) =
                run_taped(&plan, &operand, &params, &x, threads, None);
            let (fused_logits, fused_grads) =
                run_taped(&fused, &operand, &params, &x, threads, None);
            assert_eq!(
                fused_logits.data, unfused_logits.data,
                "{fname}/t{threads}: fused training value diverged"
            );
            for (name, g) in &fused_grads {
                assert_eq!(
                    g.data, unfused_grads[name].data,
                    "{fname}/t{threads}: fused grad '{name}' diverged"
                );
            }
            let unfused_inf =
                execute_inference(&plan, &operand, &params, &[&x, &x], threads).unwrap();
            let fused_inf =
                execute_inference(&fused, &operand, &params, &[&x, &x], threads).unwrap();
            for (u, f) in unfused_inf.iter().zip(&fused_inf) {
                assert_eq!(u.data, f.data, "{fname}/t{threads}: fused inference diverged");
            }
            KernelRegistry::global().unbind_context(&ctx);
        }
    }
}

/// The fusion pass end-to-end in *serving*: a session warm-started from a
/// DB that measured the fused epilogue faster serves fused — bitwise-equal
/// to an unfused co-session over the same frozen parameters, through the
/// real scheduler queue.
#[test]
fn fused_session_serves_bitwise_equal_through_scheduler() {
    let ds = karate_club();
    let model = GnnModel::Gcn;
    let dims = ModelParams { in_dim: ds.feature_dim(), hidden: HIDDEN, classes: ds.num_classes };
    let params = model.init_params(dims, 17);
    let tuner = Tuner::with_config(HardwareProfile::amd_epyc(), TuneConfig::quick());
    // training-time DB: the fused epilogue "measured" faster at the
    // fusable width (hidden); deterministic, no live measurement
    let mut db = TuningDb::default();
    db.put(
        "plan-serve-fused",
        "amd-epyc",
        HIDDEN,
        DbEntry { fuse_relu: Some(2.0), ..DbEntry::default() },
    );
    KernelRegistry::global().set_patched(true);

    let mut server = InferenceServer::new(ServeConfig {
        max_batch: 4,
        quantum: 4,
        threads: 2,
        ..ServeConfig::default()
    });
    let fused_sid = server
        .register_session(
            "plan-serve-fused",
            model,
            dims,
            params.clone(),
            &ds.adj,
            Some((&tuner, &db)),
        )
        .unwrap();
    let plain_sid = server
        .register_session("plan-serve-plain", model, dims, params, &ds.adj, None)
        .unwrap();
    assert_eq!(server.session(fused_sid).unwrap().fused_ops(), 1, "warm start must fuse");
    assert_eq!(server.session(plain_sid).unwrap().fused_ops(), 0);

    let mut rng = Rng::seed_from_u64(19);
    let xs: Vec<Dense> =
        (0..6).map(|_| Dense::uniform(34, dims.in_dim, 1.0, &mut rng)).collect();
    for x in &xs {
        server.submit(fused_sid, x.clone()).unwrap();
        server.submit(plain_sid, x.clone()).unwrap();
    }
    let done = server.run_until_drained().unwrap();
    assert_eq!(done.len(), 12);
    // pair up fused/plain completions per input and compare bitwise
    for x in &xs {
        let fused_out = done
            .iter()
            .find(|c| c.session == fused_sid && c.features.data == x.data)
            .expect("fused completion");
        let plain_out = done
            .iter()
            .find(|c| c.session == plain_sid && c.features.data == x.data)
            .expect("plain completion");
        assert_eq!(
            fused_out.expect_output().data,
            plain_out.expect_output().data,
            "fused serving diverged from unfused over the scheduler"
        );
    }
    server.close_session(fused_sid).unwrap();
    server.close_session(plain_sid).unwrap();
}

/// Trainer ↔ serving hand-off through the plan: a trainer's predict and a
/// frozen session's scheduled inference agree bitwise on the training
/// features.
#[test]
fn train_predict_and_serve_agree_bitwise() {
    use isplib::train::{Backend, FusePolicy, TrainConfig, Trainer};
    let ds = karate_club();
    let cfg = TrainConfig {
        epochs: 12,
        hidden: 8,
        skip_tuning: true,
        fuse: FusePolicy::Always,
        ..TrainConfig::default()
    };
    let mut trainer = Trainer::new(GnnModel::Gcn, Backend::NativeTuned, cfg, &ds).unwrap();
    trainer.fit(&ds).unwrap();
    assert_eq!(trainer.plan().fused_op_count(), 1);
    let want = trainer.predict(&ds).unwrap();

    let dims = ModelParams { in_dim: ds.feature_dim(), hidden: 8, classes: ds.num_classes };
    let mut server = InferenceServer::new(ServeConfig {
        max_batch: 2,
        quantum: 2,
        threads: 1,
        ..ServeConfig::default()
    });
    let sid = server
        .register_session(
            "plan-roundtrip",
            trainer.model(),
            dims,
            trainer.export_params().unwrap(),
            &ds.adj,
            None,
        )
        .unwrap();
    let got = server.infer_now(sid, &ds.features).unwrap();
    assert_eq!(got.data, want.data, "serving diverged from the trainer's predict");
}
