//! Serving subsystem integration: scheduler fairness, batch-coalescing
//! bitwise equality, concurrent multi-graph workspace use, and the
//! train → freeze → serve hand-off.

use std::sync::Arc;

use isplib::autodiff::context_graph_id;
use isplib::autotune::{DbEntry, HardwareProfile, KernelRegistry, TuneConfig, Tuner, TuningDb};
use isplib::data::karate_club;
use isplib::dense::Dense;
use isplib::gnn::{GnnModel, ModelParams};
use isplib::kernels::{
    spmm, spmm_with_workspace, KernelChoice, KernelWorkspace, Semiring,
};
use isplib::serve::{concat_cols, split_cols, InferenceServer, ServeConfig};
use isplib::sparse::{Coo, Csr};
use isplib::train::{Backend, TrainConfig, Trainer};
use isplib::util::rng::Rng;

fn random_graph(n: usize, deg: usize, seed: u64) -> Csr {
    let mut rng = Rng::seed_from_u64(seed);
    let mut coo = Coo::new(n, n);
    for r in 0..n {
        for _ in 0..deg {
            coo.push(r, rng.gen_range(n), rng.gen_range_f32(0.1, 1.0));
        }
    }
    coo.to_csr()
}

/// The identity the batcher rests on, checked against every kernel family:
/// one SpMM over column-concatenated inputs is bitwise-equal to per-input
/// SpMMs — for serial and partitioned execution alike.
#[test]
fn coalesced_spmm_bitwise_equal_across_kernels() {
    let a = random_graph(48, 5, 91);
    let mut rng = Rng::seed_from_u64(92);
    let xs: Vec<Dense> = (0..4).map(|_| Dense::uniform(48, 16, 1.0, &mut rng)).collect();
    let x_refs: Vec<&Dense> = xs.iter().collect();
    let packed = concat_cols(&x_refs).unwrap(); // 48 × 64
    for choice in [
        KernelChoice::Trusted,
        KernelChoice::Generated { kb: 16 },
        KernelChoice::Tiled { kt: 16 },
        KernelChoice::Sell { c: 4, sigma: 32 },
        KernelChoice::SortedCsr,
    ] {
        for threads in [1, 3] {
            let y = spmm(&a, &packed, Semiring::Sum, choice, threads).unwrap();
            let split = split_cols(&y, &[16; 4]).unwrap();
            for (x, part) in xs.iter().zip(&split) {
                let solo = spmm(&a, x, Semiring::Sum, choice, threads).unwrap();
                assert_eq!(
                    solo.data, part.data,
                    "coalesced SpMM diverged: choice={choice:?} threads={threads}"
                );
            }
        }
    }
}

/// Many threads hammering one shared workspace with two distinct graphs:
/// results stay correct, partitions cache per graph, buffers pool across
/// graphs.
#[test]
fn concurrent_multi_graph_workspace_use() {
    let g1 = Arc::new(random_graph(40, 4, 93));
    let g2 = Arc::new(random_graph(56, 4, 94));
    let mut rng = Rng::seed_from_u64(95);
    let x1 = Arc::new(Dense::uniform(40, 8, 1.0, &mut rng));
    let x2 = Arc::new(Dense::uniform(56, 8, 1.0, &mut rng));
    let want1 = spmm(&g1, &x1, Semiring::Sum, KernelChoice::Trusted, 2).unwrap();
    let want2 = spmm(&g2, &x2, Semiring::Sum, KernelChoice::Trusted, 2).unwrap();
    let ws = Arc::new(KernelWorkspace::new());

    std::thread::scope(|scope| {
        for t in 0..4 {
            let (graph, x, want, id) = if t % 2 == 0 {
                (Arc::clone(&g1), Arc::clone(&x1), want1.clone(), 1u64)
            } else {
                (Arc::clone(&g2), Arc::clone(&x2), want2.clone(), 2u64)
            };
            let ws = Arc::clone(&ws);
            scope.spawn(move || {
                for round in 0..10 {
                    let y = spmm_with_workspace(
                        &graph,
                        &x,
                        Semiring::Sum,
                        KernelChoice::Trusted,
                        2,
                        Some((&ws, id.into())),
                    )
                    .unwrap();
                    assert_eq!(y.data, want.data, "thread {t} round {round}");
                    ws.recycle(y.data);
                }
            });
        }
    });

    let stats = ws.stats();
    // 40 calls total over 2 (graph, threads) keys: overwhelmingly hits
    // (concurrent first-misses may compute a partition twice, never wrongly)
    assert!(stats.partition_hits >= 30, "{stats:?}");
    assert!(stats.partition_misses >= 2, "{stats:?}");
    assert!(stats.buffer_reuses > 0, "{stats:?}");
    assert!(ws.cached_partitions() >= 2);
    // per-graph eviction leaves the other tenant's entries intact
    let evicted = ws.evict(1u64);
    assert!(evicted >= 1);
    assert!(ws.cached_partitions() >= 1);
    let y = spmm_with_workspace(&g2, &x2, Semiring::Sum, KernelChoice::Trusted, 2, Some((&ws, 2u64.into())))
        .unwrap();
    assert_eq!(y.data, want2.data);
}

/// Three sessions, one flooding: deficit round robin keeps every light
/// session's completions near the front — nobody starves.
#[test]
fn scheduler_fairness_three_way_skew() {
    let mut server = InferenceServer::new(ServeConfig { max_batch: 4, quantum: 4, threads: 1, ..ServeConfig::default() });
    let graphs = [random_graph(20, 3, 96), random_graph(24, 3, 97), random_graph(28, 3, 98)];
    let mut sids = Vec::new();
    for (i, g) in graphs.iter().enumerate() {
        let dims = ModelParams { in_dim: 6, hidden: 8, classes: 3 };
        let params = GnnModel::Gin.init_params(dims, 5 + i as u64);
        let sid = server
            .register_session(&format!("skew-{i}"), GnnModel::Gin, dims, params, g, None)
            .unwrap();
        sids.push(sid);
    }
    let mut rng = Rng::seed_from_u64(99);
    // session 0 floods 48 before sessions 1 and 2 submit 4 each
    for _ in 0..48 {
        server.submit(sids[0], Dense::uniform(20, 6, 1.0, &mut rng)).unwrap();
    }
    for _ in 0..4 {
        server.submit(sids[1], Dense::uniform(24, 6, 1.0, &mut rng)).unwrap();
        server.submit(sids[2], Dense::uniform(28, 6, 1.0, &mut rng)).unwrap();
    }
    let done = server.run_until_drained().unwrap();
    assert_eq!(done.len(), 56);
    for light in [sids[1], sids[2]] {
        let last = done.iter().rposition(|c| c.session == light).unwrap();
        // both light sessions finish within the first DRR round
        // (3 sessions × quantum 4 = 12 completions)
        assert!(last < 12, "session {light:?} starved: last completion at {last}");
    }
    // every session's work completed exactly
    assert_eq!(server.metrics(sids[0]).unwrap().requests, 48);
    assert_eq!(server.metrics(sids[1]).unwrap().requests, 4);
    assert_eq!(server.metrics(sids[2]).unwrap().requests, 4);
}

/// Train on karate, freeze the params into a session, and check the
/// serving forward agrees with the trainer's own predict — while leaving
/// the trainer's backprop cache untouched.
#[test]
fn train_freeze_serve_roundtrip() {
    let ds = karate_club();
    let cfg = TrainConfig { epochs: 20, hidden: 8, skip_tuning: true, ..TrainConfig::default() };
    let mut trainer = Trainer::new(GnnModel::Gcn, Backend::NativeTuned, cfg, &ds).unwrap();
    trainer.fit(&ds).unwrap();
    let dims = ModelParams { in_dim: ds.feature_dim(), hidden: 8, classes: ds.num_classes };

    let mut server = InferenceServer::new(ServeConfig { max_batch: 4, quantum: 4, threads: 2, ..ServeConfig::default() });
    let sid = server
        .register_session(
            "karate-roundtrip",
            trainer.model(),
            dims,
            trainer.export_params().unwrap(),
            &ds.adj,
            None,
        )
        .unwrap();

    let cache_before = trainer.cache().stats();
    // serving the training features must reproduce the trainer's logits
    for _ in 0..3 {
        server.submit(sid, ds.features.clone()).unwrap();
    }
    let done = server.run_until_drained().unwrap();
    let want = trainer.predict(&ds).unwrap();
    for c in &done {
        assert!(c.expect_output().allclose(&want, 1e-5), "serving logits diverge from predict");
        assert_eq!(c.batch_size, 3);
    }
    // inference is cache-free: the trainer's BackpropCache saw nothing
    assert_eq!(trainer.cache().stats(), cache_before);
    // and the session's workspace id is derived exactly like training's
    assert_eq!(server.session(sid).unwrap().graph_id, context_graph_id("karate-roundtrip"));
}

/// A session warm-started onto a tuned SELL-C-σ decision serves from the
/// converted representation with ZERO conversions at request time: the
/// format is materialised once at registration, every request hits the
/// cache, and outputs stay bitwise-equal to the per-request reference.
#[test]
fn session_serves_from_tuned_format_without_request_time_conversion() {
    let ds = karate_club();
    let name = "karate-sell-serving";
    let dims = ModelParams { in_dim: ds.feature_dim(), hidden: 8, classes: ds.num_classes };
    let model = GnnModel::Gcn;

    // a "training-time" tuning DB that picked SELL for every width this
    // model's serving SpMMs will hit (per-request and coalesced)
    let tuner = Tuner::with_config(HardwareProfile::amd_epyc(), TuneConfig::quick());
    let mut db = TuningDb::default();
    let max_batch = 4usize;
    for k in model.lower(dims, model.norm_kind()).spmm_shapes_batched(max_batch) {
        db.put(
            name,
            "amd-epyc",
            k,
            DbEntry { sell: Some((4, 32)), speedup: 1.5, ..DbEntry::default() },
        );
    }
    KernelRegistry::global().set_patched(true);

    let mut server = InferenceServer::new(ServeConfig {
        max_batch,
        quantum: 4,
        threads: 2,
        ..ServeConfig::default()
    });
    let params = model.init_params(dims, 21);
    let sid = server
        .register_session(name, model, dims, params, &ds.adj, Some((&tuner, &db)))
        .unwrap();
    let session = server.session(sid).unwrap();
    assert!(session.warm_started > 0);
    assert_eq!(session.preconverted, 1, "one distinct SELL conversion at registration");
    let ws = Arc::clone(server.workspace());
    assert_eq!(ws.cached_formats(), 1);
    let misses_after_register = ws.stats().format_misses;
    assert_eq!(misses_after_register, 1);

    // serve a few batches; every SpMM routes to the SELL kernel via the
    // warm-started binding and hits the cached conversion
    let mut rng = Rng::seed_from_u64(23);
    let xs: Vec<Dense> =
        (0..6).map(|_| Dense::uniform(34, dims.in_dim, 1.0, &mut rng)).collect();
    for x in &xs {
        server.submit(sid, x.clone()).unwrap();
    }
    let done = server.run_until_drained().unwrap();
    assert_eq!(done.len(), 6);
    let stats = ws.stats();
    assert_eq!(
        stats.format_misses, misses_after_register,
        "request-time conversions must be zero: {stats:?}"
    );
    assert!(stats.format_hits > 0, "serving SpMMs must consume the cached format: {stats:?}");
    // bitwise: the tuned-format path equals the per-request reference
    for c in &done {
        let solo = server.infer_now(sid, &c.features).unwrap();
        assert_eq!(solo.data, c.expect_output().data, "tuned-format serving diverged");
    }
    // closing the session evicts the converted format with the graph
    server.close_session(sid).unwrap();
    assert_eq!(ws.cached_formats(), 0);
}
