//! End-to-end training integration: every backend × every model trains the
//! same datasets through the full coordinator stack (normalisation cache →
//! kernel registry → autodiff tape → optimizer), plus the patch/unpatch
//! drop-in semantics and tuner persistence.

use isplib::autotune::{HardwareProfile, KernelRegistry, TuneConfig, Tuner, TuningDb};
use isplib::coordinator::patch::{is_patched, patch, unpatch};
use isplib::data::{karate_club, spec_by_name};
use isplib::gnn::GnnModel;
use isplib::kernels::Semiring;
use isplib::train::{Backend, TrainConfig, TrainReport, Trainer};
use isplib::util::tmp::TempDir;

fn quick_cfg(epochs: usize) -> TrainConfig {
    TrainConfig { epochs, hidden: 8, skip_tuning: true, ..TrainConfig::default() }
}

fn fit(model: GnnModel, backend: Backend, epochs: usize) -> TrainReport {
    let ds = karate_club();
    let mut t = Trainer::new(model, backend, quick_cfg(epochs), &ds).unwrap();
    t.fit(&ds).unwrap()
}

#[test]
fn full_grid_karate_all_models_all_native_backends() {
    // 4 models × 5 native backends all converge and agree on numerics
    for model in GnnModel::ALL {
        let mut finals = Vec::new();
        for backend in Backend::NATIVE_ALL {
            let report = fit(model, backend, 25);
            assert!(
                report.final_loss < report.losses[0],
                "{model:?}/{backend:?}: loss {} -> {}",
                report.losses[0],
                report.final_loss
            );
            assert!(report.final_loss.is_finite());
            finals.push((backend.label(), report.final_loss));
        }
        // drop-in claim (paper §5): framework choice doesn't change results
        let base = finals[0].1;
        for (label, loss) in &finals {
            assert!(
                (loss - base).abs() < 1e-3,
                "{model:?}: {label} diverges: {finals:?}"
            );
        }
    }
}

#[test]
fn synthetic_dataset_trains() {
    let ds = spec_by_name("ogbn-protein").unwrap().instantiate(512, 3).unwrap();
    let mut t = Trainer::new(
        GnnModel::Gcn,
        Backend::NativeTrusted,
        TrainConfig { epochs: 10, hidden: 16, skip_tuning: true, ..TrainConfig::default() },
        &ds,
    )
    .unwrap();
    let report = t.fit(&ds).unwrap();
    assert!(report.final_loss < report.losses[0]);
    // class-structured features → should beat random guessing on train set
    assert!(report.train_acc > 1.0 / ds.num_classes as f64);
}

#[test]
fn patch_switches_kernels_without_changing_results() {
    let ds = karate_club();

    // bind a generated kernel for karate's hidden size under patching
    let registry = KernelRegistry::global();
    let tuner = Tuner::with_config(HardwareProfile::named("host").unwrap(), TuneConfig::quick());
    let mut db = TuningDb::default();
    patch();
    let a = GnnModel::Gcn.norm_kind().apply(&ds.adj).unwrap();
    tuner.tune("karate", &a, 8, registry, &mut db).unwrap();
    assert!(is_patched());

    let patched = fit(GnnModel::Gcn, Backend::NativeTuned, 20);

    unpatch();
    let unpatched = fit(GnnModel::Gcn, Backend::NativeTrusted, 20);

    assert!(
        (patched.final_loss - unpatched.final_loss).abs() < 1e-3,
        "patching changed numerics: {} vs {}",
        patched.final_loss,
        unpatched.final_loss
    );
    // restore default state for other tests
    unpatch();
}

#[test]
fn tuned_backend_reports_cache_hits_on_repeat_training() {
    let ds = karate_club();
    let cfg = quick_cfg(5);
    let mut t = Trainer::new(GnnModel::Gcn, Backend::NativeTuned, cfg, &ds).unwrap();
    let _ = t.fit(&ds).unwrap();
    let stats = t.cache().stats();
    // setup populated normalized + transposed entries
    assert!(stats.misses >= 2, "{stats:?}");
    assert!(t.cache().memory_bytes() > 0);
}

#[test]
fn legacy_backend_pays_setup_every_epoch() {
    // PT1-style re-normalisation: the report must still converge and the
    // numerics match PT2's
    let legacy = fit(GnnModel::Gcn, Backend::NativeLegacy, 15);
    let modern = fit(GnnModel::Gcn, Backend::NativeTrusted, 15);
    assert!((legacy.final_loss - modern.final_loss).abs() < 1e-4);
}

#[test]
fn tuning_db_roundtrip_through_disk() {
    let dir = TempDir::new().unwrap();
    let path = dir.path().join("tuning.json");
    let ds = karate_club();
    let a = GnnModel::Gcn.norm_kind().apply(&ds.adj).unwrap();

    let tuner = Tuner::with_config(HardwareProfile::named("host").unwrap(), TuneConfig::quick());
    let registry = KernelRegistry::new();
    registry.set_patched(true);
    let mut db = TuningDb::default();
    let first = tuner.tune("karate", &a, 16, &registry, &mut db).unwrap();
    db.save(&path).unwrap();

    // a new process-equivalent reloads the decision without measuring
    let mut db2 = TuningDb::load(&path).unwrap();
    let registry2 = KernelRegistry::new();
    registry2.set_patched(true);
    let second = tuner.tune("karate", &a, 16, &registry2, &mut db2).unwrap();
    assert_eq!(first, second);
    assert_eq!(registry2.resolve("karate", 16, Semiring::Sum), second);
}

#[test]
fn train_step_is_deterministic_given_seed() {
    let a = fit(GnnModel::Gin, Backend::NativeTrusted, 10);
    let b = fit(GnnModel::Gin, Backend::NativeTrusted, 10);
    assert_eq!(a.losses, b.losses);
}

#[test]
fn sage_mean_differs_from_sage_sum() {
    // mean vs sum aggregation are different models — sanity that the
    // normalisation plumbing isn't silently shared
    let sum = fit(GnnModel::SageSum, Backend::NativeTrusted, 10);
    let mean = fit(GnnModel::SageMean, Backend::NativeTrusted, 10);
    assert_ne!(sum.losses, mean.losses);
}
