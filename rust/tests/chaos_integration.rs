//! Chaos integration: deterministic fault injection against the full
//! serving stack (`--features failpoints` only).
//!
//! The acceptance story for fault-isolated serving: panics injected into
//! ONE tenant's kernels quarantine exactly that tenant, while a co-tenant
//! sharing the scheduler, the kernel workspace, and the global worker
//! pool keeps serving bitwise-identical results throughout. Every request
//! accepted by the server terminates with a typed outcome — served
//! logits, `RequestFailed`, or `SessionClosed` — and the whole failure
//! schedule reproduces exactly from a fixed failpoint seed.

#![cfg(feature = "failpoints")]

use isplib::dense::Dense;
use isplib::error::Error;
use isplib::gnn::{GnnModel, ModelParams};
use isplib::serve::{BreakerState, CompletedInference, InferenceServer, ServeConfig};
use isplib::sparse::{Coo, Csr};
use isplib::util::failpoints::{self, FailAction, FailPlan};
use isplib::util::parallel::WorkerPool;
use isplib::util::rng::Rng;

const VICTIM: &str = "chaos-victim";
const BYSTANDER: &str = "chaos-bystander";

fn random_graph(n: usize, deg: usize, seed: u64) -> Csr {
    let mut rng = Rng::seed_from_u64(seed);
    let mut coo = Coo::new(n, n);
    for r in 0..n {
        for _ in 0..deg {
            coo.push(r, rng.gen_range(n), rng.gen_range_f32(0.1, 1.0));
        }
    }
    coo.to_csr()
}

/// Two tenants on one server: a GCN victim and a GIN bystander with
/// different graphs, sharing one workspace and the global worker pool.
fn two_tenant_server() -> (InferenceServer, isplib::serve::SessionId, isplib::serve::SessionId) {
    let mut server = InferenceServer::new(ServeConfig {
        max_batch: 2,
        quantum: 2,
        threads: 2,
        quarantine_after: 2,
        probation_passes: 1,
        ..ServeConfig::default()
    });
    let g1 = random_graph(30, 4, 71);
    let g2 = random_graph(36, 4, 72);
    let dims = ModelParams { in_dim: 6, hidden: 8, classes: 3 };
    let victim = server
        .register_session(VICTIM, GnnModel::Gcn, dims, GnnModel::Gcn.init_params(dims, 1), &g1, None)
        .unwrap();
    let bystander = server
        .register_session(BYSTANDER, GnnModel::Gin, dims, GnnModel::Gin.init_params(dims, 2), &g2, None)
        .unwrap();
    (server, victim, bystander)
}

fn inputs(n: usize, count: usize, seed: u64) -> Vec<Dense> {
    let mut rng = Rng::seed_from_u64(seed);
    (0..count).map(|_| Dense::uniform(n, 6, 1.0, &mut rng)).collect()
}

/// The headline acceptance test: kernel panics injected into the victim's
/// SpMM dispatch quarantine the victim, while the bystander's concurrent
/// requests — batched through the same scheduler, workspace, and worker
/// pool — complete bitwise-equal to `infer_now`. After cooldown and a
/// clean probe the victim recovers, still on the shared pool.
#[test]
fn one_tenant_quarantines_while_its_cotenant_serves_bitwise_clean() {
    let _guard = failpoints::exclusive();
    failpoints::clear();
    let (mut server, victim, bystander) = two_tenant_server();
    let vx = inputs(30, 5, 81);
    let bx = inputs(36, 6, 82);
    // references taken BEFORE arming the failpoint (the victim's
    // infer_now would trip it too — same kernels, same tag)
    let v_ref = server.infer_now(victim, &vx[0]).unwrap();
    let b_refs: Vec<Dense> =
        bx.iter().map(|x| server.infer_now(bystander, x).unwrap()).collect();

    // every SpMM the victim's plan issues panics; the bystander's kernels
    // match neither the tag nor (therefore) the plan
    failpoints::configure(
        "kernels.spmm",
        FailPlan::always(FailAction::Panic).with_tag(VICTIM).limit(2),
    );

    let jobs_before = WorkerPool::global().jobs_executed();
    for x in &vx {
        server.submit(victim, x.clone()).unwrap();
    }
    for x in &bx {
        server.submit(bystander, x.clone()).unwrap();
    }
    let done = server.run_until_drained().unwrap();

    // typed-outcome contract: all 11 accepted requests terminated
    assert_eq!(done.len(), vx.len() + bx.len());
    // victim: two batches of 2 panicked (RequestFailed), the trip drained
    // the straggler as SessionClosed
    let v_done: Vec<&CompletedInference> =
        done.iter().filter(|c| c.session == victim).collect();
    assert_eq!(v_done.len(), 5);
    assert_eq!(
        v_done.iter().filter(|c| matches!(c.outcome, Err(Error::RequestFailed(_)))).count(),
        4
    );
    assert_eq!(
        v_done.iter().filter(|c| matches!(c.outcome, Err(Error::SessionClosed(_)))).count(),
        1
    );
    assert_eq!(server.breaker_state(victim).unwrap(), BreakerState::Quarantined);
    assert_eq!(server.metrics(victim).unwrap().quarantine_trips, 1);
    assert!(matches!(
        server.submit(victim, vx[0].clone()).unwrap_err(),
        Error::Overloaded { .. }
    ));

    // bystander: untouched — every request served, bitwise-equal to the
    // pre-fault per-request reference, in submission order per session
    let b_done: Vec<&CompletedInference> =
        done.iter().filter(|c| c.session == bystander).collect();
    assert_eq!(b_done.len(), 6);
    for (c, want) in b_done.iter().zip(&b_refs) {
        assert_eq!(
            c.expect_output().data, want.data,
            "bystander diverged under co-tenant fault load"
        );
    }
    assert_eq!(server.breaker_state(bystander).unwrap(), BreakerState::Closed);
    assert_eq!(server.metrics(bystander).unwrap().requests, 6);
    let jobs_mid = WorkerPool::global().jobs_executed();
    assert!(jobs_mid > jobs_before, "the shared pool served the bystander during the episode");

    // recovery: one pass ticks the cooldown into probation; the failpoint
    // budget is exhausted, so the probe serves clean and closes the breaker
    server.run_ready().unwrap();
    assert_eq!(server.breaker_state(victim).unwrap(), BreakerState::Probation);
    server.submit(victim, vx[0].clone()).unwrap();
    let done = server.run_until_drained().unwrap();
    assert_eq!(done.len(), 1);
    assert_eq!(done[0].expect_output().data, v_ref.data, "recovery is bitwise-clean");
    assert_eq!(server.breaker_state(victim).unwrap(), BreakerState::Closed);
    assert!(
        WorkerPool::global().jobs_executed() > jobs_mid,
        "the same shared pool serves the victim after recovery"
    );
    failpoints::clear();
}

/// Signature of one completed request: (request id, session index,
/// outcome class, served bits). Two runs with the same failpoint seed
/// must produce the identical vector of these.
type OutcomeSig = (u64, u8, u8, Vec<u32>);

fn faulted_run_signature(seed: u64) -> Vec<OutcomeSig> {
    let (mut server, victim, bystander) = two_tenant_server();
    // a coin-gated plan: fires on ~half the victim's kernel hits, in an
    // order that is a pure function of the seed and the hit sequence
    failpoints::configure(
        "kernels.spmm",
        FailPlan::always(FailAction::TransientError).with_tag(VICTIM).with_probability(0.5, seed),
    );
    let vx = inputs(30, 8, 83);
    let bx = inputs(36, 8, 84);
    let mut accepted = 0usize;
    for (v, b) in vx.iter().zip(&bx) {
        server.submit(victim, v.clone()).unwrap();
        server.submit(bystander, b.clone()).unwrap();
        accepted += 2;
    }
    let done = server.run_until_drained().unwrap();
    assert_eq!(done.len(), accepted, "every accepted request must terminate");
    let sig = done
        .iter()
        .map(|c| {
            let class = match &c.outcome {
                Ok(_) => 0u8,
                Err(Error::RequestFailed(_)) => 1,
                Err(Error::SessionClosed(_)) => 2,
                Err(Error::DeadlineExceeded(_)) => 3,
                Err(e) => panic!("untyped terminal outcome: {e}"),
            };
            let bits: Vec<u32> =
                c.output().map(|d| d.data.iter().map(|v| v.to_bits()).collect()).unwrap_or_default();
            (c.id, u8::from(c.session == bystander), class, bits)
        })
        .collect();
    failpoints::clear();
    sig
}

/// Determinism: the injected failure schedule is a pure function of the
/// failpoint seed, so an entire two-tenant serving run — interleaving,
/// outcome classes, and served bits — replays identically. A different
/// seed draws a different coin sequence, shifting the schedule.
#[test]
fn fault_schedule_replays_exactly_from_a_fixed_seed() {
    let _guard = failpoints::exclusive();
    failpoints::clear();
    let a = faulted_run_signature(2024);
    let b = faulted_run_signature(2024);
    assert_eq!(a, b, "same seed must replay the same failure schedule bit-for-bit");
    // sanity: the coin actually fired somewhere (some victim request
    // failed) and spared somewhere (some victim request served)
    let victim_classes: Vec<u8> =
        a.iter().filter(|(_, is_b, _, _)| *is_b == 0).map(|(_, _, c, _)| *c).collect();
    assert!(victim_classes.iter().any(|&c| c != 0), "p=0.5 fired at least once");
    // bystander requests all served regardless of seed
    assert!(
        a.iter().filter(|(_, is_b, _, _)| *is_b == 1).all(|(_, _, c, _)| *c == 0),
        "bystander is never collateral damage"
    );
}
