//! Observability integration: the cross-subsystem contracts of the obs
//! layer, checked from outside the crate.
//!
//! * The **disabled-path contract**: with observability off, every
//!   instrumentation call (spans, counters, histograms) performs zero heap
//!   allocation and records nothing — guarded by a counting global
//!   allocator, so a regression that sneaks a `format!` or a `Box` onto
//!   the disabled path fails loudly.
//! * **Concurrent exactness**: counters and histograms hammered from many
//!   worker-pool threads lose no updates (the registry is lock-free
//!   relaxed atomics, and relaxed is enough for totals).
//! * **Perfetto export**: spans opened on the main thread and inside pool
//!   workers export as Chrome trace-event JSON that parses back, nests,
//!   and carries the worker-pool tid mapping (worker `i` → tid `i + 1`).
//! * With `--features failpoints`: injected kernel panics drive the
//!   serving quarantine machinery, and the resulting fault counters
//!   (failed / quarantine trips / drains / rejections) surface in one
//!   registry snapshot.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::time::{Duration, Instant};

use isplib::obs::{self, ObsGuard, Span};
use isplib::util::check::{default_cases, forall};
use isplib::util::json::Json;
use isplib::util::parallel::WorkerPool;
use isplib::util::tmp::TempDir;

// --- counting allocator ---------------------------------------------------
// Thread-local so concurrently running tests on other threads don't
// pollute the count; const-init Cells so the TLS access itself never
// allocates. `try_with` guards against TLS teardown re-entry.

thread_local! {
    static COUNTING: Cell<bool> = const { Cell::new(false) };
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let _ = COUNTING.try_with(|on| {
            if on.get() {
                let _ = ALLOCS.try_with(|a| a.set(a.get() + 1));
            }
        });
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// Run `f` with allocation counting on for this thread; returns how many
/// heap allocations it performed.
fn count_allocs<F: FnOnce()>(f: F) -> u64 {
    ALLOCS.with(|a| a.set(0));
    COUNTING.with(|on| on.set(true));
    f();
    COUNTING.with(|on| on.set(false));
    ALLOCS.with(|a| a.get())
}

/// The disabled path is one relaxed atomic load: no allocation, no trace
/// event, no metric movement — for spans, counters, gauges, and
/// histograms alike.
#[test]
fn disabled_path_never_allocates_and_records_nothing() {
    let _guard = ObsGuard::disabled();
    // registration is the cold path and MAY allocate: acquire handles
    // outside the counted region, as real call sites do
    let c = obs::counter("obs_test.disabled.counter");
    let g = obs::gauge("obs_test.disabled.gauge");
    let h = obs::histogram("obs_test.disabled.hist");
    let (c0, h0) = (c.get(), h.count());
    let events0 = obs::trace_event_count();

    let n = count_allocs(|| {
        for i in 0..256u64 {
            let _span = Span::enter("obs_test.disabled.span");
            c.inc(1);
            g.set(i as f64);
            h.record(i);
        }
    });

    assert_eq!(n, 0, "disabled instrumentation performed {n} heap allocations");
    assert_eq!(c.get(), c0, "disabled counter moved");
    assert_eq!(h.count(), h0, "disabled histogram recorded");
    assert_eq!(obs::trace_event_count(), events0, "disabled span buffered an event");
}

/// Sanity inverse: with metrics on, recording on held handles moves them
/// — and still without allocating (recording is relaxed atomics only).
#[test]
fn enabled_recording_is_allocation_free_on_held_handles() {
    let _guard = ObsGuard::enabled();
    let c = obs::counter("obs_test.enabled.counter");
    let h = obs::histogram("obs_test.enabled.hist");
    let (c0, h0) = (c.get(), h.count());

    let n = count_allocs(|| {
        for i in 0..256u64 {
            c.inc(1);
            h.record(i);
        }
    });

    assert_eq!(n, 0, "recording on held handles performed {n} heap allocations");
    assert_eq!(c.get() - c0, 256);
    assert_eq!(h.count() - h0, 256);
}

/// Counters and histograms written from many pool workers at once lose
/// nothing: totals are exact for arbitrary job/iteration mixes.
#[test]
fn concurrent_pool_recording_totals_are_exact() {
    let _guard = ObsGuard::enabled();
    let pool = WorkerPool::new(4);
    let c = obs::counter("obs_test.concurrent.counter");
    let h = obs::histogram("obs_test.concurrent.hist");
    forall("obs_concurrent_totals", default_cases(), |rng| {
        let jobs_n = 1 + rng.gen_range(16);
        let per = 1 + rng.gen_range(200) as u64;
        let (c0, h0, s0) = (c.get(), h.count(), h.sum());
        let jobs: Vec<_> = (0..jobs_n)
            .map(|_| {
                let c = c.clone();
                let h = h.clone();
                move || {
                    for v in 0..per {
                        c.inc(1);
                        h.record(v);
                    }
                }
            })
            .collect();
        pool.join_all(jobs);
        let expect = jobs_n as u64 * per;
        assert_eq!(c.get() - c0, expect, "counter lost updates");
        assert_eq!(h.count() - h0, expect, "histogram lost samples");
        // sum of 0..per per job — exact, not just counted
        assert_eq!(h.sum() - s0, jobs_n as u64 * (per * (per - 1) / 2));
    });
}

/// Golden Perfetto export: a root span on main plus pool jobs produce a
/// trace that (a) parses back from its own JSON, (b) nests the worker
/// spans' starts inside the root, (c) maps worker `i` to tid `i + 1` with
/// a matching `thread_name` metadata record, and (d) loads identically
/// from the file `write_trace` produces.
#[test]
fn pool_spans_export_perfetto_json_with_worker_tids() {
    let _guard = ObsGuard::tracing();
    obs::clear_trace();
    const WORKERS: usize = 3;
    const JOBS: usize = 6;
    let pool = WorkerPool::new(WORKERS);
    let root_name = "obs_test.trace.root";
    // jobs the caller steals in join_all run without a pool.task span, so
    // the expected span count is JOBS minus the steals this batch caused
    let steals0 = pool.steals();
    {
        let _root = Span::enter(root_name).arg("jobs", Json::num(JOBS as f64));
        let jobs: Vec<_> = (0..JOBS)
            .map(|_| || std::thread::sleep(Duration::from_micros(200)))
            .collect();
        pool.join_all(jobs);
    }
    let expected_spans = JOBS - (pool.steals() - steals0) as usize;
    // worker spans close a few instructions after the batch latch fires,
    // so join_all returning does not guarantee their events are buffered
    // yet — poll briefly instead of racing
    let count_tasks = |doc: &Json| -> usize {
        doc.get("traceEvents")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .filter(|e| {
                e.get("name").ok().and_then(|n| n.as_str().ok()).map(|s| s == "pool.task")
                    == Some(true)
                    && e.get("ph").ok().and_then(|p| p.as_str().ok()).map(|s| s == "X")
                        == Some(true)
            })
            .count()
    };
    let deadline = Instant::now() + Duration::from_secs(5);
    let mut doc = obs::trace_json();
    while count_tasks(&doc) < expected_spans && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(2));
        doc = obs::trace_json();
    }
    assert_eq!(
        count_tasks(&doc),
        expected_spans,
        "expected one pool.task span per worker-executed job"
    );

    // (a) the export round-trips through the parser
    let parsed = Json::parse(&doc.pretty()).unwrap();
    let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
    let name_of = |e: &Json| e.get("name").ok().and_then(|n| n.as_str().ok()).map(String::from);
    let tid_of = |e: &Json| e.get("tid").unwrap().as_f64().unwrap() as u64;
    let root = events
        .iter()
        .find(|e| name_of(e).as_deref() == Some(root_name))
        .expect("root span exported");
    assert_eq!(tid_of(root), 0, "main thread is tid 0");
    let root_ts = root.get("ts").unwrap().as_f64().unwrap();
    let root_end = root_ts + root.get("dur").unwrap().as_f64().unwrap();

    // (b) + (c): every pool.task starts inside the root span and runs on
    // a registered worker tid
    for e in events.iter().filter(|e| name_of(e).as_deref() == Some("pool.task")) {
        let tid = tid_of(e);
        assert!(
            (1..=WORKERS as u64).contains(&tid),
            "pool.task on unexpected tid {tid}"
        );
        let ts = e.get("ts").unwrap().as_f64().unwrap();
        assert!(
            ts >= root_ts && ts <= root_end,
            "pool.task started at {ts} outside root [{root_ts},{root_end}]"
        );
        let meta = events.iter().find(|m| {
            name_of(m).as_deref() == Some("thread_name") && tid_of(m) == tid
        });
        let tname = meta
            .expect("worker tid has thread_name metadata")
            .get("args")
            .unwrap()
            .get("name")
            .unwrap()
            .as_str()
            .unwrap()
            .to_string();
        assert_eq!(tname, format!("isplib-worker-{}", tid - 1), "tid↔worker mapping");
    }

    // (d) write_trace emits the same loadable document
    let dir = TempDir::new().unwrap();
    let path = dir.path().join("trace.json");
    obs::write_trace(&path).unwrap();
    let from_disk = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    assert_eq!(
        from_disk.get("traceEvents").unwrap().as_arr().unwrap().len(),
        events.len(),
        "on-disk trace differs from the in-memory export"
    );
    obs::clear_trace();
}

// --- failpoints chaos: fault counters surface in the snapshot -------------

#[cfg(feature = "failpoints")]
mod chaos {
    use super::*;
    use isplib::dense::Dense;
    use isplib::error::Error;
    use isplib::gnn::{GnnModel, ModelParams};
    use isplib::serve::{InferenceServer, ServeConfig};
    use isplib::sparse::{Coo, Csr};
    use isplib::util::failpoints::{self, FailAction, FailPlan};
    use isplib::util::rng::Rng;

    const VICTIM: &str = "obs-victim";
    const BYSTANDER: &str = "obs-bystander";

    fn random_graph(n: usize, deg: usize, seed: u64) -> Csr {
        let mut rng = Rng::seed_from_u64(seed);
        let mut coo = Coo::new(n, n);
        for r in 0..n {
            for _ in 0..deg {
                coo.push(r, rng.gen_range(n), rng.gen_range_f32(0.1, 1.0));
            }
        }
        coo.to_csr()
    }

    /// Injected kernel panics quarantine one tenant; the episode's whole
    /// story — failed requests, the quarantine trip, the drained
    /// stragglers, the post-trip rejection, and the breaker-state gauge —
    /// is readable from a single `obs::snapshot()`.
    #[test]
    fn injected_faults_surface_in_the_registry_snapshot() {
        let _obs = ObsGuard::enabled();
        let _fp = failpoints::exclusive();
        failpoints::clear();

        let mut server = InferenceServer::new(ServeConfig {
            max_batch: 2,
            quantum: 2,
            threads: 2,
            quarantine_after: 2,
            probation_passes: 1,
            ..ServeConfig::default()
        });
        let g1 = random_graph(30, 4, 171);
        let g2 = random_graph(36, 4, 172);
        let dims = ModelParams { in_dim: 6, hidden: 8, classes: 3 };
        let victim = server
            .register_session(
                VICTIM,
                GnnModel::Gcn,
                dims,
                GnnModel::Gcn.init_params(dims, 1),
                &g1,
                None,
            )
            .unwrap();
        let bystander = server
            .register_session(
                BYSTANDER,
                GnnModel::Gin,
                dims,
                GnnModel::Gin.init_params(dims, 2),
                &g2,
                None,
            )
            .unwrap();

        let failed = obs::counter("serve.failed");
        let trips = obs::counter("serve.quarantine_trips");
        let drained = obs::counter("serve.closed_drained");
        let rejected = obs::counter("serve.rejected");
        let (f0, t0, d0, r0) = (failed.get(), trips.get(), drained.get(), rejected.get());

        failpoints::configure(
            "kernels.spmm",
            FailPlan::always(FailAction::Panic).with_tag(VICTIM).limit(2),
        );
        let mut rng = Rng::seed_from_u64(181);
        for _ in 0..5 {
            server.submit(victim, Dense::uniform(30, 6, 1.0, &mut rng)).unwrap();
        }
        for _ in 0..4 {
            server.submit(bystander, Dense::uniform(36, 6, 1.0, &mut rng)).unwrap();
        }
        let done = server.run_until_drained().unwrap();
        assert_eq!(done.len(), 9, "every accepted request terminates");
        // the quarantined session rejects at its door
        assert!(matches!(
            server.submit(victim, Dense::uniform(30, 6, 1.0, &mut rng)).unwrap_err(),
            Error::Overloaded { .. }
        ));
        failpoints::clear();

        // two panicked batches of 2, one trip, one drained straggler, one
        // post-trip rejection — as registry counter deltas
        assert_eq!(failed.get() - f0, 4, "serve.failed");
        assert_eq!(trips.get() - t0, 1, "serve.quarantine_trips");
        assert_eq!(drained.get() - d0, 1, "serve.closed_drained");
        assert_eq!(rejected.get() - r0, 1, "serve.rejected");

        server.publish_obs();
        let snap = obs::snapshot();
        let counters = snap.get("counters").unwrap();
        for key in
            ["serve.failed", "serve.quarantine_trips", "serve.closed_drained", "serve.rejected", "serve.shed_deadline"]
        {
            assert!(counters.get(key).is_ok(), "snapshot missing counter {key}");
        }
        let gauges = snap.get("gauges").unwrap();
        let breaker = gauges
            .get(&format!("serve.breaker_state{{session={VICTIM}}}"))
            .expect("victim breaker-state gauge in snapshot")
            .as_f64()
            .unwrap();
        assert!(breaker > 0.0, "victim breaker gauge should read quarantined/probation");
        let bystander_breaker = gauges
            .get(&format!("serve.breaker_state{{session={BYSTANDER}}}"))
            .unwrap()
            .as_f64()
            .unwrap();
        assert_eq!(bystander_breaker, 0.0, "bystander stays closed");
        assert!(
            gauges.get(&format!("serve.queue_depth{{session={BYSTANDER}}}")).is_ok(),
            "queue-depth gauges in snapshot"
        );
        // the pool's scattered counters are absorbed too
        assert!(gauges.get("pool.panics_caught").is_ok());
        assert!(gauges.get("pool.jobs_executed").is_ok());
    }
}
