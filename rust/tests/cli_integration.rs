//! CLI integration: drive the `isplib` binary end-to-end as a user would.

use std::process::Command;

fn isplib(args: &[&str]) -> (bool, String, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_isplib"))
        .args(args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("spawn isplib");
    (
        out.status.success(),
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
    )
}

#[test]
fn help_lists_commands() {
    let (ok, stdout, _) = isplib(&["help"]);
    assert!(ok);
    for cmd in ["probe", "datasets", "tune", "train", "bench", "serve-bench"] {
        assert!(stdout.contains(cmd), "help missing '{cmd}'");
    }
}

#[test]
fn unknown_command_fails_with_usage() {
    let (ok, _, stderr) = isplib(&["frobnicate"]);
    assert!(!ok);
    assert!(stderr.contains("unknown command"));
    assert!(stderr.contains("USAGE"));
}

#[test]
fn probe_reports_three_profiles() {
    let (ok, stdout, _) = isplib(&["probe"]);
    assert!(ok, "{stdout}");
    assert!(stdout.contains("host"));
    assert!(stdout.contains("intel-skylake"));
    assert!(stdout.contains("amd-epyc"));
    assert!(stdout.contains("best_kb"));
}

#[test]
fn datasets_prints_table1() {
    let (ok, stdout, _) = isplib(&["datasets", "--scale", "8192"]);
    assert!(ok, "{stdout}");
    for name in ["reddit", "reddit2", "ogbn-mag", "ogbn-products", "amazon", "ogbn-protein"] {
        assert!(stdout.contains(name), "table missing {name}:\n{stdout}");
    }
    assert!(stdout.contains("232965")); // paper-scale reddit nodes
}

#[test]
fn train_karate_prints_report() {
    let (ok, stdout, stderr) = isplib(&[
        "train", "--model", "gcn", "--dataset", "karate", "--backend", "pt2", "--epochs", "5",
        "--hidden", "8",
    ]);
    assert!(ok, "stdout: {stdout}\nstderr: {stderr}");
    assert!(stdout.contains("backend=PT2"));
    assert!(stdout.contains("final_loss="));
}

#[test]
fn train_json_output_parses() {
    let (ok, stdout, stderr) = isplib(&[
        "train", "--model", "gin", "--dataset", "karate", "--backend", "dense", "--epochs", "3",
        "--hidden", "8", "--json",
    ]);
    assert!(ok, "stdout: {stdout}\nstderr: {stderr}");
    let json = isplib::util::json::Json::parse(&stdout).expect("valid json");
    assert_eq!(json.get("model").unwrap().as_str().unwrap(), "gin");
    assert_eq!(json.get("losses").unwrap().as_arr().unwrap().len(), 3);
}

#[test]
fn tune_quick_sweep_renders_chart() {
    let (ok, stdout, stderr) = isplib(&[
        "tune",
        "--datasets",
        "ogbn-protein",
        "--profiles",
        "amd-epyc",
        "--ks",
        "16,32",
        "--scale",
        "4096",
    ]);
    assert!(ok, "stdout: {stdout}\nstderr: {stderr}");
    assert!(stdout.contains("tuning graph"));
    assert!(stdout.contains("ideal K"));
}

#[test]
fn bench_single_cell_reports_speedup() {
    let (ok, stdout, stderr) = isplib(&[
        "bench",
        "--models",
        "gcn",
        "--datasets",
        "ogbn-protein",
        "--frameworks",
        "isplib,pt2",
        "--epochs",
        "2",
        "--hidden",
        "16",
        "--scale",
        "4096",
    ]);
    assert!(ok, "stdout: {stdout}\nstderr: {stderr}");
    assert!(stdout.contains("iSpLib"));
    assert!(stdout.contains("PT2"));
    assert!(stdout.contains("headline speedups"));
}

#[test]
fn serve_bench_two_sessions_emit_json() {
    let dir = isplib::util::tmp::TempDir::new().unwrap();
    let out = dir.path().join("BENCH_serving.json");
    let out_str = out.to_str().unwrap();
    let (ok, stdout, stderr) = isplib(&[
        "serve-bench",
        "--datasets",
        "ogbn-protein,reddit",
        "--models",
        "gcn,sage-sum",
        "--requests",
        "6",
        "--skew",
        "3",
        "--epochs",
        "2",
        "--hidden",
        "8",
        "--scale",
        "8192",
        "--out",
        out_str,
    ]);
    assert!(ok, "stdout: {stdout}\nstderr: {stderr}");
    // the bench's own acceptance checks passed (it exits non-zero otherwise)
    assert!(stdout.contains("verified"), "{stdout}");
    assert!(stdout.contains("cache untouched"), "{stdout}");
    assert!(stdout.contains("fairness p99 spread"), "{stdout}");
    let json = isplib::util::json::Json::parse(&std::fs::read_to_string(&out).unwrap())
        .expect("valid BENCH_serving.json");
    let sessions = json.get("sessions").unwrap().as_arr().unwrap();
    assert_eq!(sessions.len(), 2);
    let checks = json.get("checks").unwrap();
    assert!(checks.get("batched_bitwise_equal").unwrap().as_bool().unwrap());
    assert!(checks.get("backprop_cache_untouched").unwrap().as_bool().unwrap());
    assert!(checks.get("shared_pool_jobs").unwrap().as_f64().unwrap() > 0.0);
    // skewed offered load actually reached the scheduler
    assert!(sessions[0].get("offered").unwrap().as_f64().unwrap() == 18.0);
    assert!(sessions[1].get("offered").unwrap().as_f64().unwrap() == 6.0);
}

#[test]
fn serve_bench_rejects_single_session() {
    let (ok, _, stderr) = isplib(&["serve-bench", "--datasets", "reddit"]);
    assert!(!ok);
    assert!(stderr.contains("2 sessions"), "{stderr}");
}

#[test]
fn train_rejects_unknown_names() {
    let (ok, _, stderr) = isplib(&["train", "--model", "gat"]);
    assert!(!ok);
    assert!(stderr.contains("gat"));
    let (ok, _, stderr) = isplib(&["train", "--dataset", "cora"]);
    assert!(!ok);
    assert!(stderr.contains("cora"));
}
