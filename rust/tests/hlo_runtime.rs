//! Integration tests: the full AOT bridge — python/jax/pallas lowers to HLO
//! text (`make artifacts`), the Rust runtime loads + compiles + executes it
//! via PJRT, and the numerics match the native Rust kernels.
//!
//! These tests are skipped (not failed) when `artifacts/` has not been
//! built, so `cargo test` works on a fresh clone; CI runs `make test`
//! which builds artifacts first. The whole file is additionally gated on
//! the `xla` cargo feature: without it the PJRT runtime is stubbed out and
//! there is nothing to exercise.
#![cfg(feature = "xla")]

use std::path::{Path, PathBuf};

use isplib::data::karate_club;
use isplib::dense::Dense;
use isplib::gnn::GnnModel;
use isplib::kernels::{spmm_dense_ref, Semiring};
use isplib::runtime::{
    dense_to_literal, f32_mat_literal, i32_mat_literal, literal_to_dense, ArtifactManifest,
    EllMatrix, HloExecutable, HloGnnTrainer,
};
use isplib::sparse::Coo;
use isplib::train::{Backend, TrainConfig, Trainer};
use isplib::util::rng::Rng;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        None
    }
}

fn random_graph(n: usize, deg: usize, seed: u64) -> isplib::sparse::Csr {
    let mut rng = Rng::seed_from_u64(seed);
    let mut coo = Coo::new(n, n);
    for r in 0..n {
        for _ in 0..deg {
            coo.push(r, rng.gen_range(n), rng.gen_range_f32(0.1, 1.0));
        }
    }
    coo.to_csr()
}

#[test]
fn manifest_lists_all_models() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = ArtifactManifest::load(&dir).unwrap();
    for model in ["gcn", "sage-sum", "sage-mean", "gin"] {
        assert!(
            manifest.find_train_step(model, 34, 34, 2).is_some(),
            "missing karate artifact for {model}"
        );
    }
    assert!(manifest.find_spmm(64, 16).is_some());
    assert!(!manifest.jax_version.is_empty());
}

#[test]
fn hlo_spmm_matches_native_kernels() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = ArtifactManifest::load(&dir).unwrap();
    let entry = manifest.find_spmm(64, 16).unwrap();
    let exe = HloExecutable::load(&entry.hlo_path(&dir)).unwrap();

    let a = random_graph(64, 6, 91);
    let ell = EllMatrix::from_csr(&a, entry.ell_width).unwrap().widen(entry.ell_width).unwrap();
    assert!(ell.fits(entry.n, entry.ell_width), "graph too dense for artifact");

    let mut rng = Rng::seed_from_u64(92);
    let x = Dense::uniform(64, entry.feature_dim, 1.0, &mut rng);

    let cols = i32_mat_literal(&ell.col_idx, entry.n, entry.ell_width).expect("cols literal");
    let vals = f32_mat_literal(&ell.values, entry.n, entry.ell_width).expect("vals literal");
    let xlit = dense_to_literal(&x).unwrap();

    let out = exe.run(&[cols, vals, xlit]).unwrap();
    assert_eq!(out.len(), 1);
    let got = literal_to_dense(&out[0]).unwrap();

    let want = spmm_dense_ref(&a, &x, Semiring::Sum).unwrap();
    assert!(
        got.allclose(&want, 1e-3),
        "HLO spmm diverges from native: max diff {}",
        got.max_abs_diff(&want)
    );
}

#[test]
fn hlo_trainer_loss_decreases_on_karate() {
    let Some(dir) = artifacts_dir() else { return };
    let ds = karate_club();
    let mut t =
        HloGnnTrainer::load(&dir, GnnModel::Gcn, &ds, 8, 42).expect("load karate gcn artifact");
    let first = t.step().unwrap();
    let mut last = first;
    for _ in 0..30 {
        last = t.step().unwrap();
    }
    assert!(first.is_finite() && last.is_finite());
    assert!(last < first, "HLO training did not reduce loss: {first} -> {last}");
    // parameters round-trip to host with the manifest shapes
    let params = t.params_to_host().unwrap();
    assert_eq!(params.len(), 4);
    assert_eq!(params.get("w0").unwrap().rows, 34);
}

#[test]
fn hlo_first_loss_matches_native_first_loss() {
    // Same seed → same init (rust initialises params for both engines), so
    // the first loss of the compiled step must match the native tape's
    // first loss. This is the HLO-vs-native parity check.
    let Some(dir) = artifacts_dir() else { return };
    let ds = karate_club();

    let cfg = TrainConfig {
        epochs: 1,
        hidden: 8,
        seed: 42,
        artifacts_dir: Some(dir.clone()),
        skip_tuning: true,
        ..TrainConfig::default()
    };

    let mut native = Trainer::new(GnnModel::Gcn, Backend::NativeTrusted, cfg.clone(), &ds).unwrap();
    let native_report = native.fit(&ds).unwrap();

    let mut hlo = Trainer::new(GnnModel::Gcn, Backend::Hlo, cfg, &ds).unwrap();
    let hlo_report = hlo.fit(&ds).unwrap();

    let (a, b) = (native_report.losses[0], hlo_report.losses[0]);
    assert!(
        (a - b).abs() < 1e-4,
        "first-step loss parity broken: native {a} vs hlo {b}"
    );
}

#[test]
fn hlo_trainer_all_models() {
    let Some(dir) = artifacts_dir() else { return };
    let ds = karate_club();
    for model in GnnModel::ALL {
        let mut t = HloGnnTrainer::load(&dir, model, &ds, 8, 1)
            .unwrap_or_else(|e| panic!("load {model:?}: {e}"));
        let first = t.step().unwrap();
        for _ in 0..10 {
            t.step().unwrap();
        }
        let last = t.step().unwrap();
        assert!(last < first, "{model:?}: {first} -> {last}");
    }
}
