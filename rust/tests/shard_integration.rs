//! Topology-aware sharding integration: the bitwise-equality matrix.
//!
//! The sharding layer (`kernels/shard.rs`) claims that shard-lowered
//! execution is **bitwise-equal** to flat execution — values and
//! gradients — because each shard's gathered panel renames columns
//! monotonically without reordering any row's non-zero stream, and the
//! merge writes disjoint row ranges. These tests pin that claim where it
//! matters: through the `ExecutionPlan`, for every model of the zoo,
//! across {1, 2, 4, rows+} shards × {CSR, SELL-C-σ, sorted CSR} ×
//! {unfused, fused epilogue} × taped training / tape-free inference
//! (solo and coalesced) — plus the serving path, where the shard count
//! arrives via the tuning DB's warm-started shard axis.

use std::collections::BTreeMap;
use std::sync::Arc;

use isplib::autodiff::{context_graph_id, SpmmOperand, Tape};
use isplib::autotune::{
    DbEntry, HardwareProfile, KernelRegistry, RegistryEntry, TuneConfig, Tuner, TuningDb,
};
use isplib::data::karate_club;
use isplib::dense::Dense;
use isplib::gnn::{GnnModel, ModelParams, ParamSet};
use isplib::kernels::{KernelChoice, KernelWorkspace, Semiring};
use isplib::plan::{execute_inference, execute_taped, ExecutionPlan};
use isplib::serve::{InferenceServer, ServeConfig};
use isplib::sparse::Csr;
use isplib::util::rng::Rng;

const HIDDEN: usize = 24;

fn setup(model: GnnModel) -> (ExecutionPlan, Csr, ParamSet, ModelParams, Dense) {
    let ds = karate_club();
    let dims = ModelParams { in_dim: ds.feature_dim(), hidden: HIDDEN, classes: ds.num_classes };
    let plan = model.lower(dims, model.norm_kind());
    let params = model.init_params(dims, 23);
    let a = model.norm_kind().apply(&ds.adj).unwrap();
    let mut rng = Rng::seed_from_u64(29);
    let x = Dense::uniform(a.rows, dims.in_dim, 1.0, &mut rng).map(|v| v - 0.5);
    (plan, a, params, dims, x)
}

/// Bind `choice` for every SpMM width of `plan` under `context`.
fn bind_choice(context: &str, plan: &ExecutionPlan, choice: KernelChoice) {
    let registry = KernelRegistry::global();
    registry.set_patched(true);
    for k in plan.spmm_shapes() {
        registry.bind(context, k, Semiring::Sum, RegistryEntry { choice, speedup: 1.0 });
    }
}

/// Run the taped executor; returns (logits, per-param grads by name).
fn run_taped(
    plan: &ExecutionPlan,
    operand: &SpmmOperand,
    params: &ParamSet,
    x: &Dense,
    threads: usize,
    ws: Option<Arc<KernelWorkspace>>,
) -> (Dense, BTreeMap<String, Dense>) {
    let mut tape = match ws {
        Some(ws) => Tape::with_workspace(threads, ws),
        None => Tape::new(threads),
    };
    let xv = tape.input(x.clone());
    let mut vars = BTreeMap::new();
    for (name, value) in params.iter() {
        vars.insert(name.clone(), tape.input(value.clone()));
    }
    let logits = execute_taped(plan, &mut tape, operand, xv, &vars).unwrap();
    let labels: Vec<usize> = (0..x.rows).map(|i| i % plan.dims().classes).collect();
    let loss = tape.softmax_xent(logits, &labels, None).unwrap();
    tape.backward(loss).unwrap();
    let value = tape.value(logits).clone();
    let grads = vars
        .iter()
        .map(|(name, var)| (name.clone(), tape.grad(*var).unwrap().clone()))
        .collect();
    (value, grads)
}

/// The property matrix. For every cell, the flat (shards = 1) execution
/// is the oracle; every shard count — including one far above the row
/// count, which degenerates to fewer non-empty shards — must reproduce
/// it bitwise, values AND gradients, on both executors. The `64` column
/// is the integration-level degenerate-shard guard: karate club has 34
/// rows, so most requested shards are empty and must neither panic in
/// the halo merge nor perturb a single bit.
#[test]
fn sharded_execution_is_bitwise_equal_across_the_matrix() {
    let formats = [
        ("csr", KernelChoice::Trusted),
        ("sell", KernelChoice::Sell { c: 4, sigma: 32 }),
        ("sorted", KernelChoice::SortedCsr),
    ];
    for model in GnnModel::ALL {
        let (plan, a, params, _, x) = setup(model);
        let fused_plan = plan.fuse_spmm_relu(|_| true);
        for (fname, choice) in formats {
            for fused in [false, true] {
                let base = if fused { &fused_plan } else { &plan };
                let ctx = format!("shard-matrix-{}-{fname}-{fused}", model.name());
                bind_choice(&ctx, &plan, choice);
                let ws = Arc::new(KernelWorkspace::new());
                let operand = SpmmOperand::cached(a.clone(), &ctx)
                    .with_workspace(Arc::clone(&ws), context_graph_id(&ctx));

                // flat oracle for this (model, format, fusion) cell
                let (flat_logits, flat_grads) =
                    run_taped(base, &operand, &params, &x, 2, Some(Arc::clone(&ws)));
                let flat_inf = execute_inference(base, &operand, &params, &[&x], 2).unwrap();
                assert_eq!(flat_inf[0].data, flat_logits.data);

                for shards in [2usize, 4, 64] {
                    let label = format!("{model:?}/{fname}/fused={fused}/shards={shards}");
                    let sharded = base.clone().with_shards(shards);
                    assert_eq!(sharded.shards(), shards);

                    let (logits, grads) =
                        run_taped(&sharded, &operand, &params, &x, 2, Some(Arc::clone(&ws)));
                    assert_eq!(logits.data, flat_logits.data, "{label}: taped value");
                    for (name, g) in &grads {
                        assert_eq!(
                            g.data, flat_grads[name].data,
                            "{label}: grad '{name}' diverged"
                        );
                    }

                    let solo =
                        execute_inference(&sharded, &operand, &params, &[&x], 2).unwrap();
                    assert_eq!(solo[0].data, flat_logits.data, "{label}: inference value");
                    let batch =
                        execute_inference(&sharded, &operand, &params, &[&x, &x, &x], 2)
                            .unwrap();
                    for out in &batch {
                        assert_eq!(out.data, flat_logits.data, "{label}: coalesced inference");
                    }
                }
                KernelRegistry::global().unbind_context(&ctx);
            }
        }
    }
}

/// Shard-local workspace state accumulates while executing sharded —
/// cached shard plans (and, for format-bound contexts, their per-shard
/// conversions) — and the flat oracle above proved it never changes a
/// bit. Here: the cache actually populates and hits, so the second
/// execution builds nothing.
#[test]
fn shard_plans_cache_across_executions() {
    let (plan, a, params, _, x) = setup(GnnModel::Gcn);
    let ctx = "shard-cache-test";
    bind_choice(ctx, &plan, KernelChoice::Trusted);
    let ws = Arc::new(KernelWorkspace::new());
    let operand =
        SpmmOperand::cached(a, ctx).with_workspace(Arc::clone(&ws), context_graph_id(ctx));
    let sharded = plan.with_shards(2);
    let first = execute_inference(&sharded, &operand, &params, &[&x], 2).unwrap();
    let misses = ws.stats().shard_misses;
    assert!(misses > 0, "sharded execution must build shard plans");
    assert!(ws.cached_shard_plans() > 0);
    let second = execute_inference(&sharded, &operand, &params, &[&x], 2).unwrap();
    assert_eq!(first[0].data, second[0].data);
    assert_eq!(ws.stats().shard_misses, misses, "warm execution rebuilds nothing");
    assert!(ws.stats().shard_hits > 0);
    KernelRegistry::global().unbind_context(ctx);
}

/// Sharding end-to-end in *serving*: a session whose tuning DB carries a
/// shard decision serves shard-lowered — bitwise-equal to a flat
/// co-session over the same frozen parameters, through the real
/// scheduler queue and micro-batcher.
#[test]
fn sharded_session_serves_bitwise_equal_through_scheduler() {
    let ds = karate_club();
    let model = GnnModel::Gcn;
    let dims = ModelParams { in_dim: ds.feature_dim(), hidden: HIDDEN, classes: ds.num_classes };
    let params = model.init_params(dims, 31);
    let tuner = Tuner::with_config(HardwareProfile::amd_epyc(), TuneConfig::quick());
    // the shard axis keys on the widest coalesced width this session can
    // execute (max_batch = ServeConfig::max_batch below)
    let widest =
        *model.lower(dims, model.norm_kind()).spmm_shapes_batched(4).last().unwrap();
    let mut db = TuningDb::default();
    db.put(
        "shard-serve-sharded",
        "amd-epyc",
        widest,
        DbEntry { speedup: 1.1, shards: Some(2), ..DbEntry::default() },
    );
    KernelRegistry::global().set_patched(true);

    let mut server = InferenceServer::new(ServeConfig {
        max_batch: 4,
        quantum: 4,
        threads: 2,
        ..ServeConfig::default()
    });
    let sharded_sid = server
        .register_session(
            "shard-serve-sharded",
            model,
            dims,
            params.clone(),
            &ds.adj,
            Some((&tuner, &db)),
        )
        .unwrap();
    let flat_sid = server
        .register_session("shard-serve-flat", model, dims, params, &ds.adj, None)
        .unwrap();
    assert_eq!(
        server.session(sharded_sid).unwrap().plan().shards(),
        2,
        "warm start must shard-lower the session plan"
    );
    assert_eq!(server.session(flat_sid).unwrap().plan().shards(), 1);

    let mut rng = Rng::seed_from_u64(37);
    let xs: Vec<Dense> = (0..6).map(|_| Dense::uniform(34, dims.in_dim, 1.0, &mut rng)).collect();
    for x in &xs {
        server.submit(sharded_sid, x.clone()).unwrap();
        server.submit(flat_sid, x.clone()).unwrap();
    }
    let done = server.run_until_drained().unwrap();
    assert_eq!(done.len(), 12);
    for x in &xs {
        let sharded_out = done
            .iter()
            .find(|c| c.session == sharded_sid && c.features.data == x.data)
            .expect("sharded completion");
        let flat_out = done
            .iter()
            .find(|c| c.session == flat_sid && c.features.data == x.data)
            .expect("flat completion");
        assert_eq!(
            sharded_out.expect_output().data,
            flat_out.expect_output().data,
            "sharded serving diverged from flat over the scheduler"
        );
    }
    server.close_session(sharded_sid).unwrap();
    server.close_session(flat_sid).unwrap();
}

/// Fault injection at the `kernels.halo_merge` site (`--features
/// failpoints`): the one cross-shard write of a sharded dispatch. A
/// panic there must propagate out of the pool (no torn output escapes —
/// the merge target is only published on success), and once disarmed the
/// very next call is bitwise-clean; a delay there reorders shard
/// completion without perturbing a single bit.
#[cfg(feature = "failpoints")]
mod chaos {
    use std::panic::{catch_unwind, AssertUnwindSafe};
    use std::time::Duration;

    use isplib::kernels::{spmm_sharded, ShardPlan};
    use isplib::util::failpoints::{self, fires, FailAction, FailPlan};

    use super::*;

    #[test]
    fn panic_in_halo_merge_propagates_and_disarmed_rerun_is_clean() {
        let _guard = failpoints::exclusive();
        failpoints::clear();
        let ds = karate_club();
        let a = &ds.adj;
        let mut rng = Rng::seed_from_u64(41);
        let x = Dense::uniform(a.rows, 16, 1.0, &mut rng);
        let flat =
            spmm_sharded(a, &x, Semiring::Sum, KernelChoice::Trusted, 2, None, 1).unwrap();

        failpoints::configure(
            "kernels.halo_merge",
            FailPlan::always(FailAction::Panic).limit(1),
        );
        let caught = catch_unwind(AssertUnwindSafe(|| {
            spmm_sharded(a, &x, Semiring::Sum, KernelChoice::Trusted, 2, None, 4)
        }));
        assert!(caught.is_err(), "injected merge panic must propagate to the caller");
        failpoints::clear();

        let after = spmm_sharded(a, &x, Semiring::Sum, KernelChoice::Trusted, 2, None, 4)
            .unwrap();
        assert_eq!(after.data, flat.data, "disarmed rerun must be bitwise-clean");
    }

    #[test]
    fn delay_in_halo_merge_fires_per_shard_and_never_perturbs_bits() {
        let _guard = failpoints::exclusive();
        failpoints::clear();
        let ds = karate_club();
        let a = &ds.adj;
        let mut rng = Rng::seed_from_u64(43);
        let x = Dense::uniform(a.rows, 16, 1.0, &mut rng);
        let flat =
            spmm_sharded(a, &x, Semiring::Sum, KernelChoice::Trusted, 2, None, 1).unwrap();
        let jobs = ShardPlan::build(a, 4).shard_count();

        failpoints::configure(
            "kernels.halo_merge",
            FailPlan::always(FailAction::Delay(Duration::from_millis(2))),
        );
        let before = fires("kernels.halo_merge");
        let slow = spmm_sharded(a, &x, Semiring::Sum, KernelChoice::Trusted, 2, None, 4)
            .unwrap();
        assert_eq!(
            fires("kernels.halo_merge") - before,
            jobs as u64,
            "the merge failpoint fires once per shard job"
        );
        assert_eq!(slow.data, flat.data, "a delayed merge changes timing, never bits");
        failpoints::clear();
    }
}
