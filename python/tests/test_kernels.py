"""L1 kernel correctness: Pallas kernels vs pure-jnp references.

hypothesis sweeps shapes, sparsity patterns, block geometries and
semirings; every property pins the kernel to ``ref.py``.  This is the CORE
correctness signal for the compile path — if these pass, the HLO the AOT
pipeline ships computes the right thing.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fusedmm_ell, ref, sddmm_ell, spmm_ell

SEMIRINGS = ["sum", "max", "min", "mean"]


def make_ell(rng, n, w, m, density=0.6):
    cols = rng.integers(0, m, (n, w)).astype(np.int32)
    vals = rng.uniform(0.2, 1.5, (n, w)).astype(np.float32)
    vals[rng.uniform(size=(n, w)) >= density] = 0.0
    return cols, vals


@st.composite
def spmm_case(draw):
    n = draw(st.integers(2, 24))
    w = draw(st.integers(1, 8))
    m = draw(st.integers(2, 24))
    k = draw(st.integers(1, 20))
    seed = draw(st.integers(0, 2**31 - 1))
    rb = draw(st.sampled_from([1, 4, 8, 32]))
    kb = draw(st.sampled_from([1, 4, 8, 32]))
    return n, w, m, k, seed, rb, kb


@settings(max_examples=40, deadline=None)
@given(case=spmm_case(), reduce=st.sampled_from(SEMIRINGS))
def test_spmm_matches_ref(case, reduce):
    n, w, m, k, seed, rb, kb = case
    rng = np.random.default_rng(seed)
    cols, vals = make_ell(rng, n, w, m)
    x = rng.normal(size=(m, k)).astype(np.float32)
    got = spmm_ell(cols, vals, x, reduce=reduce, row_block=rb, k_block=kb)
    want = ref.spmm_ell_ref(cols, vals, x, reduce)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(2, 20),
    w=st.integers(1, 6),
    d=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
    rb=st.sampled_from([1, 8, 32]),
)
def test_sddmm_matches_ref(n, w, d, seed, rb):
    rng = np.random.default_rng(seed)
    cols, vals = make_ell(rng, n, w, n)
    u = rng.normal(size=(n, d)).astype(np.float32)
    v = rng.normal(size=(n, d)).astype(np.float32)
    got = sddmm_ell(cols, vals, u, v, row_block=rb)
    want = ref.sddmm_ell_ref(cols, vals, u, v)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(2, 16),
    w=st.integers(1, 5),
    d=st.integers(1, 6),
    k=st.integers(1, 12),
    seed=st.integers(0, 2**31 - 1),
)
def test_fusedmm_matches_unfused(n, w, d, k, seed):
    rng = np.random.default_rng(seed)
    cols, vals = make_ell(rng, n, w, n)
    u = rng.normal(size=(n, d)).astype(np.float32)
    v = rng.normal(size=(n, d)).astype(np.float32)
    x = rng.normal(size=(n, k)).astype(np.float32)
    got = fusedmm_ell(cols, vals, u, v, x, row_block=8, k_block=8)
    want = ref.fusedmm_ell_ref(cols, vals, u, v, x)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(2, 16),
    k=st.integers(1, 12),
    seed=st.integers(0, 2**31 - 1),
)
def test_block_geometry_invariance(n, k, seed):
    """The tuning knob (block sizes) must never change numerics — the same
    routing-invariance property the Rust side property-tests."""
    rng = np.random.default_rng(seed)
    cols, vals = make_ell(rng, n, 4, n)
    x = rng.normal(size=(n, k)).astype(np.float32)
    base = spmm_ell(cols, vals, x, row_block=1, k_block=1)
    for rb in (2, 8, 64):
        for kb in (2, 8, 64):
            got = spmm_ell(cols, vals, x, row_block=rb, k_block=kb)
            np.testing.assert_allclose(got, base, rtol=1e-5, atol=1e-5)


def test_empty_rows_are_zero_all_semirings():
    cols = np.zeros((4, 3), np.int32)
    vals = np.zeros((4, 3), np.float32)
    x = np.ones((4, 5), np.float32)
    for reduce in SEMIRINGS:
        out = np.asarray(spmm_ell(cols, vals, x, reduce=reduce))
        assert np.all(out == 0.0), reduce


def test_unknown_reduce_rejected():
    cols = np.zeros((2, 1), np.int32)
    vals = np.zeros((2, 1), np.float32)
    x = np.zeros((2, 2), np.float32)
    with pytest.raises(ValueError):
        spmm_ell(cols, vals, x, reduce="prod")
    with pytest.raises(ValueError):
        fusedmm_ell(cols, vals, x, x, x, edge_op="relu")


def test_padding_is_neutral():
    """Widening the ELL with (0, 0.0) padding never changes the result."""
    rng = np.random.default_rng(3)
    cols, vals = make_ell(rng, 6, 3, 6, density=1.0)
    x = rng.normal(size=(6, 4)).astype(np.float32)
    base = spmm_ell(cols, vals, x)
    wide_cols = np.zeros((6, 8), np.int32)
    wide_vals = np.zeros((6, 8), np.float32)
    wide_cols[:, :3] = cols
    wide_vals[:, :3] = vals
    wide = spmm_ell(wide_cols, wide_vals, x)
    np.testing.assert_allclose(wide, base, rtol=1e-6, atol=1e-6)
