"""AOT pipeline tests: lowering produces parseable HLO text with the right
argument/manifest contract. (The Rust side's hlo_runtime tests cover
load+execute; these tests validate the producer.)"""

import json
import os

import pytest

from compile.aot import KARATE, SPMM_SHAPES, SYNTH, lower_spmm, lower_train_step
from compile.model import MODELS


def test_lower_spmm_text_and_entry():
    text, entry = lower_spmm(16, 4, 8)
    assert "HloModule" in text
    assert entry["kind"] == "spmm"
    assert entry["n"] == 16
    assert entry["ell_width"] == 4
    assert entry["feature_dim"] == 8
    assert entry["param_names"] == []


@pytest.mark.parametrize("model", MODELS)
def test_lower_train_step_contract(model):
    text, entry = lower_train_step(model, n=10, w=4, f=6, h=4, c=2, lr=0.1)
    assert "HloModule" in text
    # fused step: forward + backward + SGD in ONE module — no python at
    # train time, and the L1 pallas kernel lowered inline
    assert entry["kind"] == "train_step"
    assert entry["model"] == model
    assert entry["param_names"] == sorted(entry["param_names"])
    assert len(entry["param_names"]) == len(entry["param_shapes"])
    # parameter argument order must match the manifest exactly:
    # count parameters of the entry point
    n_params = len(entry["param_names"])
    assert n_params in (4, 6)
    # lr is recorded so the runtime knows what the compiled SGD does
    assert entry["lr"] == 0.1


def test_cached_backward_avoids_adjacency_scatter():
    """§3.3 structural check (the L2 perf invariant): with the cached
    transpose as an input, the adjacency gather's autodiff must NOT appear
    as a scatter-add in the lowered module.  One scatter per module remains
    from the cross-entropy's take_along_axis gradient, so the check
    compares against an *uncached* lowering (plain spmm_ell, whose gather
    XLA differentiates into scatter-adds): cached must have strictly fewer
    scatters, and at most the xent one per... module."""
    import jax
    import jax.numpy as jnp

    from compile.aot import f32, i32, to_hlo_text
    from compile.kernels import ref
    from compile.model import masked_xent, param_shapes

    n, w, f, h, c = 10, 4, 6, 4, 2

    def count_scatters(text):
        return text.lower().count(" scatter(")

    # cached lowering (the shipped artifact)
    text, _ = lower_train_step("gcn", n=n, w=w, f=f, h=h, c=c, lr=0.1)
    cached_scatters = count_scatters(text)

    # uncached lowering: same model but aggregation via the plain jnp
    # reference — XLA autodiffs its gather into scatter-adds (the PT2-ish
    # form; the pallas kernel itself has no reverse rule, which is exactly
    # why the shipped artifact needs the custom VJP)
    shapes = param_shapes("gcn", f, h, c)
    names = sorted(shapes)

    def uncached_step(*args):
        k = len(names)
        params = dict(zip(names, args[:k]))
        x, cols, vals, labels, mask = args[k:]

        def loss_fn(p):
            spmm = lambda hh: ref.spmm_ell_ref(cols, vals, hh, "sum")
            hid = jax.nn.relu(spmm(x @ p["w0"]) + p["b0"])
            logits = spmm(hid @ p["w1"]) + p["b1"]
            return masked_xent(logits, labels, mask)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        new = jax.tree_util.tree_map(lambda pp, gg: pp - 0.1 * gg, params, grads)
        return tuple(new[nm] for nm in names) + (loss,)

    args = [f32(*shapes[nm]) for nm in names]
    args += [f32(n, f), i32(n, w), f32(n, w), i32(n), f32(n)]
    uncached_text = to_hlo_text(jax.jit(uncached_step).lower(*args))
    uncached_scatters = count_scatters(uncached_text)

    assert cached_scatters < uncached_scatters, (
        f"cached {cached_scatters} vs uncached {uncached_scatters}: "
        "the cached transpose did not eliminate adjacency scatters"
    )


def test_artifact_name_uniqueness():
    names = set()
    for model in MODELS:
        for shape in (KARATE, SYNTH):
            _, entry = lower_train_step(model, **shape)
            assert entry["name"] not in names
            names.add(entry["name"])
    for n, w, k in SPMM_SHAPES:
        _, entry = lower_spmm(n, w, k)
        assert entry["name"] not in names
        names.add(entry["name"])


def test_manifest_on_disk_if_built():
    """If `make artifacts` has run, the manifest must agree with the files
    next to it (guards against stale manifests)."""
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    mpath = os.path.join(art, "manifest.json")
    if not os.path.exists(mpath):
        pytest.skip("artifacts not built")
    with open(mpath) as fh:
        manifest = json.load(fh)
    assert manifest["entries"], "empty manifest"
    for entry in manifest["entries"]:
        hlo = os.path.join(art, entry["name"] + ".hlo.txt")
        assert os.path.exists(hlo), f"manifest lists missing file {hlo}"
        with open(hlo) as fh:
            head = fh.read(200)
        assert "HloModule" in head
