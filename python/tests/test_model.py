"""L2 model correctness: forwards, gradients, the cached-backprop VJP, and
the train step that aot.py lowers."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref, spmm_ell_cached
from compile.model import (MODELS, flat_train_step, forward, init_params,
                           make_train_step, masked_xent, param_shapes)


def make_graph(rng, n, w, symmetric=True):
    cols = rng.integers(0, n, (n, w)).astype(np.int32)
    vals = rng.uniform(0.2, 1.0, (n, w)).astype(np.float32)
    vals[rng.uniform(size=(n, w)) < 0.3] = 0.0
    if symmetric:
        # build a symmetric matrix by mirroring through dense form
        dense = np.zeros((n, n), np.float32)
        for i in range(n):
            for j in range(w):
                if vals[i, j] != 0.0:
                    dense[i, cols[i, j]] = vals[i, j]
        dense = np.maximum(dense, dense.T)
        width = max(1, int((dense != 0).sum(1).max()))
        cols = np.zeros((n, width), np.int32)
        vals = np.zeros((n, width), np.float32)
        for i in range(n):
            nz = np.nonzero(dense[i])[0]
            cols[i, :len(nz)] = nz
            vals[i, :len(nz)] = dense[i, nz]
    return cols, vals


def transpose_ell(cols, vals, n):
    # duplicates within a row are summed by the kernel, so accumulate (+=)
    dense = np.zeros((n, n), np.float32)
    for i in range(cols.shape[0]):
        for j in range(cols.shape[1]):
            if vals[i, j] != 0.0:
                dense[i, cols[i, j]] += vals[i, j]
    dt = dense.T
    width = max(1, int((dt != 0).sum(1).max()), cols.shape[1])
    ct = np.zeros((n, width), np.int32)
    vt = np.zeros((n, width), np.float32)
    for i in range(n):
        nz = np.nonzero(dt[i])[0]
        ct[i, :len(nz)] = nz
        vt[i, :len(nz)] = dt[i, nz]
    return ct, vt


def test_cached_vjp_matches_autodiff_of_reference():
    """The custom VJP (backward = spmm over the cached transpose) must equal
    jax.grad of the plain reference — §3.3 caching cannot change gradients."""
    rng = np.random.default_rng(0)
    n, w, k = 10, 4, 6
    cols, vals = make_graph(rng, n, w, symmetric=False)
    cols_t, vals_t = transpose_ell(cols, vals, n)
    x = rng.normal(size=(n, k)).astype(np.float32)

    def loss_cached(x):
        return spmm_ell_cached(cols, vals, cols_t, vals_t, x).sum()

    def loss_ref(x):
        return ref.spmm_ell_ref(cols, vals, x, "sum").sum()

    g_cached = jax.grad(loss_cached)(x)
    g_ref = jax.grad(loss_ref)(x)
    np.testing.assert_allclose(g_cached, g_ref, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("model", MODELS)
def test_forward_shapes_and_finiteness(model):
    rng = np.random.default_rng(1)
    n, w, f, h, c = 12, 4, 7, 5, 3
    cols, vals = make_graph(rng, n, w)
    cols_t, vals_t = cols, vals  # symmetric
    x = rng.normal(size=(n, f)).astype(np.float32)
    params = init_params(model, f, h, c, seed=0)
    logits = forward(model, params, x, cols, vals, cols_t, vals_t)
    assert logits.shape == (n, c)
    assert np.all(np.isfinite(logits))


@pytest.mark.parametrize("model", MODELS)
def test_training_reduces_loss(model):
    rng = np.random.default_rng(2)
    n, w, f, h, c = 16, 4, 8, 6, 2
    cols, vals = make_graph(rng, n, w)
    x = rng.normal(size=(n, f)).astype(np.float32)
    labels = jnp.asarray(rng.integers(0, c, n), jnp.int32)
    mask = jnp.ones((n,), jnp.float32)
    params = init_params(model, f, h, c, seed=1)
    step = make_train_step(model, c, lr=0.2)
    losses = []
    for _ in range(15):
        params, loss = step(params, x, cols, vals, cols, vals, labels, mask)
        losses.append(float(loss))
    assert losses[-1] < losses[0], f"{model}: {losses[0]} -> {losses[-1]}"
    assert all(np.isfinite(l) for l in losses)


def test_masked_xent_matches_manual():
    logits = jnp.asarray([[2.0, 0.0], [0.0, 1.0], [1.0, 1.0]])
    labels = jnp.asarray([0, 1, 0], jnp.int32)
    mask = jnp.asarray([1.0, 1.0, 0.0])
    got = float(masked_xent(logits, labels, mask))
    logp = jax.nn.log_softmax(logits)
    want = float(-(logp[0, 0] + logp[1, 1]) / 2.0)
    assert abs(got - want) < 1e-6


def test_mask_excludes_rows_from_gradient():
    rng = np.random.default_rng(3)
    logits = jnp.asarray(rng.normal(size=(3, 2)).astype(np.float32))
    labels = jnp.asarray([0, 1, 0], jnp.int32)
    mask = jnp.asarray([1.0, 0.0, 1.0])
    g = jax.grad(lambda z: masked_xent(z, labels, mask))(logits)
    assert np.all(np.asarray(g)[1] == 0.0)


@pytest.mark.parametrize("model", MODELS)
def test_flat_train_step_signature(model):
    """The AOT argument convention: sorted param names, then statics, and
    output = params' + loss. This is the contract the manifest records."""
    f, h, c = 6, 4, 2
    flat, names, shapes = flat_train_step(model, f, h, c, lr=0.1)
    assert names == sorted(shapes)
    rng = np.random.default_rng(4)
    n, w = 9, 3
    cols, vals = make_graph(rng, n, w)
    x = rng.normal(size=(n, f)).astype(np.float32)
    labels = jnp.asarray(rng.integers(0, c, n), jnp.int32)
    mask = jnp.ones((n,), jnp.float32)
    args = [jnp.zeros(shapes[nm], jnp.float32) for nm in names]
    out = flat(*args, x, cols, vals, cols, vals, labels, mask)
    assert len(out) == len(names) + 1
    for nm, new in zip(names, out[:-1]):
        assert new.shape == shapes[nm]
    assert out[-1].shape == ()


def test_param_shapes_match_rust_side():
    # mirror of rust/src/gnn/models.rs param_counts test
    assert len(param_shapes("gcn", 10, 4, 3)) == 4
    assert len(param_shapes("sage-sum", 10, 4, 3)) == 6
    assert len(param_shapes("gin", 10, 4, 3)) == 6
    with pytest.raises(ValueError):
        param_shapes("gat", 10, 4, 3)
