"""AOT pipeline: lower the L2 train steps + standalone kernels to HLO text.

Run once by ``make artifacts``:

    cd python && python -m compile.aot --out-dir ../artifacts

Emits one ``<name>.hlo.txt`` per entry plus ``manifest.json`` (the contract
``rust/src/runtime/manifest.rs`` consumes).  HLO **text** is the
interchange format, not ``.serialize()``: jax ≥ 0.5 emits HloModuleProto
with 64-bit instruction ids which the runtime's xla_extension 0.5.1
rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly.  See /opt/xla-example/README.md.

Python never runs at train time — the Rust binary is self-contained once
this script has produced ``artifacts/``.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .kernels import spmm_ell
from .model import MODELS, flat_train_step, param_shapes


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def lower_train_step(model: str, n: int, w: int, f: int, h: int, c: int,
                     lr: float):
    """Lower one (model, shape) train step; returns (hlo_text, entry)."""
    flat, names, shapes = flat_train_step(model, f, h, c, lr)
    args = [f32(*shapes[name]) for name in names]
    args += [
        f32(n, f),   # features
        i32(n, w),   # ell cols
        f32(n, w),   # ell vals (pre-normalised by the coordinator)
        i32(n, w),   # ell cols of Aᵀ (the §3.3 cached transpose)
        f32(n, w),   # ell vals of Aᵀ
        i32(n),      # labels
        f32(n),      # train mask (1.0/0.0)
    ]
    lowered = jax.jit(flat).lower(*args)
    entry = {
        "name": f"{model.replace('-', '_')}_n{n}_f{f}_h{h}_c{c}",
        "kind": "train_step",
        "model": model,
        "n": n,
        "ell_width": w,
        "feature_dim": f,
        "hidden": h,
        "classes": c,
        "lr": lr,
        "param_names": names,
        "param_shapes": [list(shapes[nm]) for nm in names],
    }
    return to_hlo_text(lowered), entry


def lower_spmm(n: int, w: int, k: int):
    """Standalone SpMM artifact (runtime smoke tests + HLO-kernel bench)."""
    fn = lambda cols, vals, x: (spmm_ell(cols, vals, x, reduce="sum"),)
    lowered = jax.jit(fn).lower(i32(n, w), f32(n, w), f32(n, k))
    entry = {
        "name": f"spmm_n{n}_w{w}_k{k}",
        "kind": "spmm",
        "model": "",
        "n": n,
        "ell_width": w,
        "feature_dim": k,
        "hidden": 0,
        "classes": 0,
        "lr": 0.0,
        "param_names": [],
        "param_shapes": [],
    }
    return to_hlo_text(lowered), entry


# The artifact set: every model at karate-club shape (the end-to-end
# example + parity tests) and one synthetic shape, plus standalone SpMMs.
KARATE = dict(n=34, w=32, f=34, h=8, c=2, lr=0.1)
SYNTH = dict(n=256, w=64, f=16, h=16, c=4, lr=0.1)
SPMM_SHAPES = [(64, 16, 16), (256, 64, 32)]


def build_all(out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    entries = []
    for model in MODELS:
        for shape in (KARATE, SYNTH):
            text, entry = lower_train_step(model, **shape)
            path = os.path.join(out_dir, entry["name"] + ".hlo.txt")
            with open(path, "w") as fh:
                fh.write(text)
            entries.append(entry)
            print(f"wrote {path} ({len(text)} chars)")
    for n, w, k in SPMM_SHAPES:
        text, entry = lower_spmm(n, w, k)
        path = os.path.join(out_dir, entry["name"] + ".hlo.txt")
        with open(path, "w") as fh:
            fh.write(text)
        entries.append(entry)
        print(f"wrote {path} ({len(text)} chars)")

    manifest = {"jax_version": jax.__version__, "entries": entries}
    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as fh:
        json.dump(manifest, fh, indent=2)
    print(f"wrote {mpath} ({len(entries)} entries)")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    build_all(args.out_dir)


if __name__ == "__main__":
    main()
