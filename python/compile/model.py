"""L2: JAX GNN models (GCN / GraphSAGE-sum / GraphSAGE-mean / GIN).

The forward passes mirror ``rust/src/gnn/models.rs`` op-for-op so the
HLO-vs-native parity tests can compare losses on identical parameters:

* GCN projects features *before* the SpMM (the paper's §5 point about why
  GCN benefits most from tuned kernels),
* SAGE aggregates raw features first,
* GIN is ``MLP((1+ε)·x + Σ neighbours)`` with ε = 0.

All aggregation goes through the L1 Pallas kernel ``spmm_ell_cached``,
whose custom VJP consumes the *pre-transposed* adjacency — the paper's
cache-enabled backprop (§3.3) expressed at the JAX level.  The adjacency
arrives pre-normalised from the Rust coordinator (it owns the
normalisation cache), so every model here reduces to sum-semiring SpMM.

The training step (cross-entropy on masked nodes + SGD) is a single jitted
function; ``aot.py`` lowers it to HLO text per static shape.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from .kernels import spmm_ell_cached

MODELS = ("gcn", "sage-sum", "sage-mean", "gin")


def param_shapes(model: str, f: int, h: int, c: int) -> Dict[str, tuple]:
    """Parameter name → shape, matching rust's GnnModel::init_params."""
    if model == "gcn":
        return {"w0": (f, h), "b0": (1, h), "w1": (h, c), "b1": (1, c)}
    if model in ("sage-sum", "sage-mean"):
        return {
            "w0_self": (f, h), "w0_neigh": (f, h), "b0": (1, h),
            "w1_self": (h, c), "w1_neigh": (h, c), "b1": (1, c),
        }
    if model == "gin":
        return {
            "w0a": (f, h), "b0a": (1, h), "w0b": (h, h), "b0b": (1, h),
            "w1": (h, c), "b1": (1, c),
        }
    raise ValueError(f"unknown model '{model}'")


def forward(model: str, params: Dict[str, jnp.ndarray], x, cols, vals,
            cols_t, vals_t):
    """Two-layer GNN forward; returns logits [n, c]."""
    spmm = lambda h: spmm_ell_cached(cols, vals, cols_t, vals_t, h)
    if model == "gcn":
        h = spmm(x @ params["w0"]) + params["b0"]
        h = jax.nn.relu(h)
        return spmm(h @ params["w1"]) + params["b1"]
    if model in ("sage-sum", "sage-mean"):
        # mean vs sum is decided by the (row-normalised) vals the Rust
        # coordinator ships — the compute graph is identical
        h = x @ params["w0_self"] + spmm(x) @ params["w0_neigh"] + params["b0"]
        h = jax.nn.relu(h)
        return h @ params["w1_self"] + spmm(h) @ params["w1_neigh"] + params["b1"]
    if model == "gin":
        z = x + spmm(x)
        h = jax.nn.relu(z @ params["w0a"] + params["b0a"])
        h = jax.nn.relu(h @ params["w0b"] + params["b0b"])
        z = h + spmm(h)
        return z @ params["w1"] + params["b1"]
    raise ValueError(f"unknown model '{model}'")


def masked_xent(logits, labels, mask):
    """Masked mean softmax cross-entropy (matches rust's softmax_xent)."""
    logp = jax.nn.log_softmax(logits)
    picked = jnp.take_along_axis(logp, labels[:, None], axis=1)[:, 0]
    total = -(picked * mask).sum()
    count = jnp.maximum(mask.sum(), 1.0)
    return total / count


def make_train_step(model: str, c: int, lr: float):
    """Build the fused train-step fn: (params…, statics…) → (params…, loss).

    Parameters are passed as individual positional arrays in sorted-name
    order (matching rust's ParamSet iteration), so the AOT artifact's
    argument list is self-describing via the manifest.
    """
    names = None  # resolved at first call via closure below
    del c

    def step(params: Dict[str, jnp.ndarray], x, cols, vals, cols_t, vals_t,
             labels, mask):
        def loss_fn(p):
            logits = forward(model, p, x, cols, vals, cols_t, vals_t)
            return masked_xent(logits, labels, mask)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        new_params = jax.tree_util.tree_map(lambda p, g: p - lr * g, params, grads)
        return new_params, loss

    del names
    return step


def flat_train_step(model: str, f: int, h: int, c: int, lr: float):
    """Flat-argument train step for AOT lowering.

    Signature: ``(p_0, …, p_{k-1}, x, cols, vals, cols_t, vals_t, labels,
    mask) -> (p_0', …, p_{k-1}', loss)`` with parameters in sorted-name
    order (the manifest records the names).
    """
    shapes = param_shapes(model, f, h, c)
    names = sorted(shapes)
    step = make_train_step(model, c, lr)

    def flat(*args):
        k = len(names)
        params = dict(zip(names, args[:k]))
        x, cols, vals, cols_t, vals_t, labels, mask = args[k:]
        new_params, loss = step(params, x, cols, vals, cols_t, vals_t,
                                labels, mask)
        return tuple(new_params[n] for n in names) + (loss,)

    return flat, names, shapes


def init_params(model: str, f: int, h: int, c: int, seed: int = 0):
    """Glorot-uniform init (same family as the Rust side; exact parity of
    trajectories is checked from identical *explicit* params in tests)."""
    shapes = param_shapes(model, f, h, c)
    params = {}
    key = jax.random.PRNGKey(seed)
    for name in sorted(shapes):
        key, sub = jax.random.split(key)
        r, cdim = shapes[name]
        if name.startswith("b"):
            params[name] = jnp.zeros((r, cdim), jnp.float32)
        else:
            scale = (6.0 / (r + cdim)) ** 0.5
            params[name] = jax.random.uniform(
                sub, (r, cdim), jnp.float32, -scale, scale)
    return params
