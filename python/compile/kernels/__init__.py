"""L1 Pallas kernels (ELL layout) + pure-jnp references."""

from .fusedmm import fusedmm_ell
from .sddmm import sddmm_ell
from .spmm import spmm_ell, spmm_ell_cached
from . import ref

__all__ = ["spmm_ell", "spmm_ell_cached", "sddmm_ell", "fusedmm_ell", "ref"]
