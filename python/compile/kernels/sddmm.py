"""L1 Pallas SDDMM kernel over the ELL layout.

``S[i, j] = vals[i, j] * <u[i, :], v[cols[i, j], :]>`` — the sampled
dense-dense product that iSpLib names alongside SpMM (paper §1(a)).

Tiling: the grid walks row blocks; each step keeps the ``(RB, W)``
neighbour tile, the ``(RB, D)`` strip of U and the whole ``(m, D)`` V panel
in VMEM, emitting the ``(RB, W)`` edge-value tile.  The feature dim D is
the contraction axis, so it is not tiled (GNN attention dims are small).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


def _sddmm_kernel(cols_ref, vals_ref, u_ref, v_ref, o_ref):
    cols = cols_ref[...]                 # (RB, W)
    vals = vals_ref[...]                 # (RB, W)
    u = u_ref[...]                       # (RB, D)
    v = v_ref[...]                       # (m, D)
    dots = jnp.einsum("rd,rwd->rw", u, v[cols])
    o_ref[...] = vals * dots


def sddmm_ell(cols, vals, u, v, *, row_block: int = 32):
    """SDDMM over an ELL pattern; returns the new edge values (n × w)."""
    n, w = cols.shape
    m, d = v.shape
    rb = min(row_block, n)
    grid = (_cdiv(n, rb),)
    return pl.pallas_call(
        _sddmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rb, w), lambda i: (i, 0)),
            pl.BlockSpec((rb, w), lambda i: (i, 0)),
            pl.BlockSpec((rb, d), lambda i: (i, 0)),
            pl.BlockSpec((m, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((rb, w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, w), vals.dtype),
        interpret=True,
    )(cols, vals, u, v)
