"""L1 Pallas FusedMM kernel: SDDMM + SpMM in one grid pass (FusedMM [8]).

The unfused pipeline materialises an ``(n, w)`` edge-value tensor between
the two kernels; fusing keeps each ``(RB, W)`` edge tile in VMEM only for
the lifetime of one grid step and writes only the ``(RB, KB)`` output tile
— exactly the traffic-halving argument of the FusedMM paper, restated for
the HBM↔VMEM boundary instead of DRAM↔cache.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


def _fusedmm_kernel(cols_ref, vals_ref, u_ref, v_ref, x_ref, o_ref, *, edge_op: str):
    cols = cols_ref[...]                  # (RB, W)
    vals = vals_ref[...]                  # (RB, W)
    u = u_ref[...]                        # (RB, D)
    v = v_ref[...]                        # (m, D)
    x = x_ref[...]                        # (m, KB)
    dots = jnp.einsum("rd,rwd->rw", u, v[cols])
    if edge_op == "dot":
        edge = vals * dots
    elif edge_op == "sigmoid":
        edge = vals * jax.nn.sigmoid(dots)
    else:  # pragma: no cover - guarded by the wrapper
        raise ValueError(edge_op)
    gathered = x[cols]                    # (RB, W, KB)
    o_ref[...] = jnp.sum(edge[:, :, None] * gathered, axis=1)


def fusedmm_ell(cols, vals, u, v, x, *, edge_op: str = "dot",
                row_block: int = 32, k_block: int = 32):
    """Fused SDDMM→SpMM: ``Y[i,:] = Σ_j g(vals, <u_i, v_cols>) x[cols[i,j],:]``."""
    if edge_op not in ("dot", "sigmoid"):
        raise ValueError(f"unknown edge op '{edge_op}'")
    n, w = cols.shape
    m, k = x.shape
    _, d = v.shape
    rb = min(row_block, n)
    kb = min(k_block, k)
    grid = (_cdiv(n, rb), _cdiv(k, kb))
    kernel = functools.partial(_fusedmm_kernel, edge_op=edge_op)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rb, w), lambda i, j: (i, 0)),
            pl.BlockSpec((rb, w), lambda i, j: (i, 0)),
            pl.BlockSpec((rb, d), lambda i, j: (i, 0)),
            pl.BlockSpec((m, d), lambda i, j: (0, 0)),
            pl.BlockSpec((m, kb), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((rb, kb), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, k), x.dtype),
        interpret=True,
    )(cols, vals, u, v, x)
