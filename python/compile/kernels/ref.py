"""Pure-jnp correctness oracles for the L1 Pallas kernels.

Every kernel in this package is validated against these references by
``python/tests/`` (pytest + hypothesis).  The references are deliberately
written in the most obvious jnp form — no tiling, no tricks — so a mismatch
always indicts the kernel.

Sparse operands use the ELL (padded fixed-width) layout that the AOT path
ships to the Rust runtime: ``cols[i, j]``/``vals[i, j]`` give the j-th
neighbour of row i, padded with ``(col=0, val=0.0)`` which is neutral for a
sum semiring and masked explicitly for min/max/mean.
"""

from __future__ import annotations

import jax.numpy as jnp


def spmm_ell_ref(cols, vals, x, reduce: str = "sum"):
    """Reference semiring SpMM over an ELL adjacency.

    Args:
      cols: int32[n, w] neighbour column ids (padded with 0).
      vals: float32[n, w] edge values (padding entries are exactly 0.0).
      x:    float32[m, k] dense features.
      reduce: 'sum' | 'max' | 'min' | 'mean' (paper §3.4/§3.5).

    Returns:
      float32[n, k]: per-row reduction of ``vals[i,j] * x[cols[i,j], :]``.
      Rows whose entries are all padding produce zeros for every semiring,
      matching pytorch_sparse and the Rust kernels.
    """
    gathered = x[cols]                        # [n, w, k]
    messages = vals[:, :, None] * gathered    # [n, w, k]
    valid = (vals != 0.0)[:, :, None]         # padding mask
    nnz = jnp.sum(valid, axis=1)              # [n, 1]

    if reduce == "sum":
        return jnp.sum(jnp.where(valid, messages, 0.0), axis=1)
    if reduce == "mean":
        total = jnp.sum(jnp.where(valid, messages, 0.0), axis=1)
        return jnp.where(nnz > 0, total / jnp.maximum(nnz, 1), 0.0)
    if reduce == "max":
        filled = jnp.where(valid, messages, -jnp.inf)
        out = jnp.max(filled, axis=1)
        return jnp.where(nnz > 0, out, 0.0)
    if reduce == "min":
        filled = jnp.where(valid, messages, jnp.inf)
        out = jnp.min(filled, axis=1)
        return jnp.where(nnz > 0, out, 0.0)
    raise ValueError(f"unknown reduce '{reduce}'")


def sddmm_ell_ref(cols, vals, u, v):
    """Reference SDDMM: per stored edge, ``vals[i,j] * <u[i], v[cols[i,j]]>``.

    Returns float32[n, w] edge values sharing the ELL pattern.  Padding
    entries stay 0 because their ``vals`` factor is 0.
    """
    dots = jnp.einsum("ik,ijk->ij", u, v[cols])  # [n, w]
    return vals * dots


def fusedmm_ell_ref(cols, vals, u, v, x):
    """Reference FusedMM (dot edge-op): SDDMM then SpMM, unfused."""
    edge = sddmm_ell_ref(cols, vals, u, v)
    return spmm_ell_ref(cols, edge, x, "sum")
