"""L1 Pallas SpMM kernel over the ELL layout.

Hardware adaptation (DESIGN.md §2): the paper's CPU kernel register-blocks
the feature dimension so a K-strip of the output row stays in SIMD
registers across the whole neighbour stream.  On a TPU the same insight
maps to *VMEM tiling*: the grid walks ``(row_block, k_block)`` tiles, the
``k_block`` width playing the role of the paper's VLEN-multiple — it is
the knob the auto-tuner sweeps.  Each grid step keeps

  * a ``(ROW_BLOCK, W)`` slice of the ELL neighbour lists, and
  * the ``(m, K_BLOCK)`` feature panel

resident in VMEM and accumulates ``(ROW_BLOCK, K_BLOCK)`` outputs in one
shot — dense rectangular math on the VPU instead of the CPU's serial CSR
row stream.

The kernels run with ``interpret=True`` everywhere in this repo: the CPU
PJRT plugin cannot execute real Mosaic lowerings, so correctness is
validated through the interpreter and TPU performance is *estimated*
statically (EXPERIMENTS.md §Perf) from the BlockSpec geometry.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


def _spmm_kernel(cols_ref, vals_ref, x_ref, o_ref, *, reduce: str):
    """One (row_block × k_block) grid step."""
    cols = cols_ref[...]                     # (RB, W) int32
    vals = vals_ref[...]                     # (RB, W) f32
    x = x_ref[...]                           # (m, KB) f32
    gathered = x[cols]                       # (RB, W, KB)
    messages = vals[:, :, None] * gathered   # (RB, W, KB)
    valid = (vals != 0.0)[:, :, None]
    if reduce == "sum":
        o_ref[...] = jnp.sum(jnp.where(valid, messages, 0.0), axis=1)
    elif reduce == "mean":
        nnz = jnp.sum(valid, axis=1)
        total = jnp.sum(jnp.where(valid, messages, 0.0), axis=1)
        o_ref[...] = jnp.where(nnz > 0, total / jnp.maximum(nnz, 1), 0.0)
    elif reduce == "max":
        filled = jnp.where(valid, messages, -jnp.inf)
        out = jnp.max(filled, axis=1)
        o_ref[...] = jnp.where(jnp.any(valid, axis=1), out, 0.0)
    elif reduce == "min":
        filled = jnp.where(valid, messages, jnp.inf)
        out = jnp.min(filled, axis=1)
        o_ref[...] = jnp.where(jnp.any(valid, axis=1), out, 0.0)
    else:  # pragma: no cover - guarded by the wrapper
        raise ValueError(reduce)


def spmm_ell(cols, vals, x, *, reduce: str = "sum",
             row_block: int = 32, k_block: int = 32):
    """Semiring SpMM ``Y[i,:] = reduce_j vals[i,j] * x[cols[i,j],:]``.

    Args:
      cols: int32[n, w] ELL neighbour ids (0-padded).
      vals: float32[n, w] edge values (0.0-padded).
      x:    float32[m, k] dense features.
      reduce: 'sum' | 'max' | 'min' | 'mean'.
      row_block/k_block: VMEM tile geometry (the tuning knobs).

    Returns float32[n, k].
    """
    if reduce not in ("sum", "max", "min", "mean"):
        raise ValueError(f"unknown reduce '{reduce}'")
    n, w = cols.shape
    m, k = x.shape
    rb = min(row_block, n)
    kb = min(k_block, k)
    grid = (_cdiv(n, rb), _cdiv(k, kb))
    kernel = functools.partial(_spmm_kernel, reduce=reduce)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rb, w), lambda i, j: (i, 0)),      # neighbour ids
            pl.BlockSpec((rb, w), lambda i, j: (i, 0)),      # edge values
            pl.BlockSpec((m, kb), lambda i, j: (0, j)),      # feature panel
        ],
        out_specs=pl.BlockSpec((rb, kb), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, k), x.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic lowerings
    )(cols, vals, x)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def spmm_ell_cached(cols, vals, cols_t, vals_t, x, row_block=32, k_block=32):
    """SpMM (sum) with **cache-enabled backprop** (paper §3.3).

    The backward of ``Y = A @ X`` w.r.t. ``X`` is ``Aᵀ @ dY``.  Without
    intervention XLA differentiates the gather into a scatter-add — the
    "uncached" form that re-derives the transpose's access pattern on every
    step.  This wrapper instead takes the transpose ``(cols_t, vals_t)`` as
    an *input* (computed once by the Rust coordinator's BackpropCache) and
    its custom VJP runs the same forward kernel over it — the L2 half of
    iSpLib's cached backpropagation.
    """
    return spmm_ell(cols, vals, x, reduce="sum",
                    row_block=row_block, k_block=k_block)


def _spmm_cached_fwd(cols, vals, cols_t, vals_t, x, row_block, k_block):
    y = spmm_ell(cols, vals, x, reduce="sum",
                 row_block=row_block, k_block=k_block)
    return y, (cols_t, vals_t)


def _spmm_cached_bwd(row_block, k_block, res, g):
    cols_t, vals_t = res
    dx = spmm_ell(cols_t, vals_t, g, reduce="sum",
                  row_block=row_block, k_block=k_block)
    # no gradients for the (static) sparse structure
    return None, None, None, None, dx


spmm_ell_cached.defvjp(_spmm_cached_fwd, _spmm_cached_bwd)
