//! Semiring SpMM (paper §3.4/§3.5): the pytorch_sparse-style `matmul`
//! interface with sum / mean / max / min reductions, used to build
//! GraphSAGE variants — plus the SDDMM and FusedMM micro-kernels.
//!
//! ```text
//! cargo run --release --example semiring_sage
//! ```

use isplib::data::spec_by_name;
use isplib::dense::Dense;
use isplib::error::Result;
use isplib::gnn::GnnModel;
use isplib::kernels::{fusedmm, sddmm, spmm, EdgeOp, KernelChoice, Semiring};
use isplib::train::{Backend, TrainConfig, Trainer};
use isplib::util::rng::Rng;

fn main() -> Result<()> {
    let ds = spec_by_name("ogbn-protein").expect("spec").instantiate(256, 11)?;
    println!("dataset {}: {} nodes, {} edges", ds.name, ds.num_nodes(), ds.num_edges());

    // --- the matmul interface: one call per reduction ----------------------
    let mut rng = Rng::seed_from_u64(5);
    let x = Dense::uniform(ds.num_nodes(), 16, 1.0, &mut rng);
    for op in Semiring::ALL {
        let y = spmm(&ds.adj, &x, op, KernelChoice::Trusted, 1)?;
        let norm: f32 = y.frobenius();
        println!("matmul(adj, x, reduce='{}') → frobenius {:.3}", op.name(), norm);
    }

    // --- SDDMM + FusedMM micro-kernels (the user-definable ops of §1(a)) ---
    let u = Dense::uniform(ds.num_nodes(), 8, 1.0, &mut rng);
    let v = Dense::uniform(ds.num_nodes(), 8, 1.0, &mut rng);
    let edge_scores = sddmm(&ds.adj, &u, &v, 1)?;
    println!(
        "sddmm: edge-score matrix keeps the pattern ({} nnz)",
        edge_scores.nnz()
    );
    let fused = fusedmm(&ds.adj, &x, Some(&u), Some(&v), EdgeOp::SigmoidDot, 1)?;
    println!("fusedmm(sigmoid-gated): output {}x{}", fused.rows, fused.cols);

    // --- GraphSAGE with sum vs mean aggregation ----------------------------
    for model in [GnnModel::SageSum, GnnModel::SageMean] {
        let cfg = TrainConfig { epochs: 15, hidden: 16, skip_tuning: true, ..TrainConfig::default() };
        let mut trainer = Trainer::new(model, Backend::NativeTrusted, cfg, &ds)?;
        let report = trainer.fit(&ds)?;
        println!(
            "{:<10} loss {:.4} → {:.4}, test acc {:.2}",
            model.name(),
            report.losses[0],
            report.final_loss,
            report.test_acc
        );
    }
    Ok(())
}
