//! END-TO-END DRIVER — proves all three layers compose on real workloads.
//!
//! ```text
//! make artifacts && cargo run --release --example end_to_end_training
//! ```
//!
//! 1. Trains a 2-layer GCN on the real karate-club graph with the **native**
//!    stack (Rust kernels + autodiff tape + tuner) and logs the loss curve.
//! 2. Trains the same model through the **AOT/HLO** stack: the JAX+Pallas
//!    train step compiled by `make artifacts`, loaded and executed from
//!    Rust via PJRT — no Python anywhere in this process.
//! 3. Cross-checks the two stacks' first-step losses (parity) and reports
//!    per-epoch timings for both.
//! 4. Repeats (native) on a scaled synthetic Reddit to show the system at
//!    generator scale. Results are recorded in EXPERIMENTS.md §E2E.

use isplib::data::{karate_club, spec_by_name};
use isplib::error::Result;
use isplib::gnn::GnnModel;
use isplib::train::{Backend, TrainConfig, Trainer};

fn sparkline(values: &[f32]) -> String {
    const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    let max = values.iter().cloned().fold(f32::MIN, f32::max);
    let min = values.iter().cloned().fold(f32::MAX, f32::min);
    let span = (max - min).max(1e-9);
    values
        .iter()
        .map(|v| BARS[(((v - min) / span) * 7.0).round() as usize])
        .collect()
}

fn main() -> Result<()> {
    let karate = karate_club();
    println!("=== stage 1: native stack on karate club (real graph) ===");
    let cfg = TrainConfig { epochs: 80, hidden: 8, ..TrainConfig::default() };
    let mut native = Trainer::new(GnnModel::Gcn, Backend::NativeTuned, cfg.clone(), &karate)?;
    let native_report = native.fit(&karate)?;
    println!("loss curve: {}", sparkline(&native_report.losses));
    println!(
        "epochs={} first_loss={:.4} final_loss={:.4} train_acc={:.2} test_acc={:.2} avg_epoch={:.6}s",
        native_report.losses.len(),
        native_report.losses[0],
        native_report.final_loss,
        native_report.train_acc,
        native_report.test_acc,
        native_report.avg_epoch_secs()
    );
    assert!(native_report.final_loss < 0.2, "native GCN failed to fit karate");

    println!("\n=== stage 2: AOT/HLO stack (JAX+Pallas → XLA → PJRT, no Python) ===");
    // resolve artifacts/ relative to cwd, falling back to the crate root
    let mut artifacts = std::path::PathBuf::from("artifacts");
    if !artifacts.join("manifest.json").exists() {
        artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    }
    if !artifacts.join("manifest.json").exists() {
        println!("artifacts/ missing — run `make artifacts` first; skipping HLO stage");
    } else {
        let cfg_hlo = TrainConfig {
            epochs: 80,
            hidden: 8,
            artifacts_dir: Some(artifacts),
            ..TrainConfig::default()
        };
        let mut hlo = Trainer::new(GnnModel::Gcn, Backend::Hlo, cfg_hlo, &karate)?;
        let hlo_report = hlo.fit(&karate)?;
        println!("loss curve: {}", sparkline(&hlo_report.losses));
        println!(
            "epochs={} first_loss={:.4} final_loss={:.4} train_acc={:.2} test_acc={:.2} avg_epoch={:.6}s",
            hlo_report.losses.len(),
            hlo_report.losses[0],
            hlo_report.final_loss,
            hlo_report.train_acc,
            hlo_report.test_acc,
            hlo_report.avg_epoch_secs()
        );
        // layer-parity: identical params at step 0 → identical first loss
        let drift = (native_report.losses[0] - hlo_report.losses[0]).abs();
        println!("first-step parity |native - hlo| = {drift:.6}");
        assert!(drift < 1e-4, "stacks disagree at step 0");
        assert!(hlo_report.final_loss < 0.5, "HLO GCN failed to fit karate");
    }

    println!("\n=== stage 3: native stack on synthetic Reddit (1/512 scale) ===");
    let reddit = spec_by_name("reddit").expect("spec").instantiate(512, 7)?;
    println!(
        "generated {}: {} nodes, {} edges, {} features, {} classes",
        reddit.name,
        reddit.num_nodes(),
        reddit.num_edges(),
        reddit.feature_dim(),
        reddit.num_classes
    );
    let cfg = TrainConfig { epochs: 20, hidden: 32, ..TrainConfig::default() };
    let mut trainer = Trainer::new(GnnModel::Gcn, Backend::NativeTuned, cfg, &reddit)?;
    let report = trainer.fit(&reddit)?;
    println!("loss curve: {}", sparkline(&report.losses));
    println!(
        "first_loss={:.4} final_loss={:.4} train_acc={:.2} avg_epoch={:.6}s setup={:.3}s",
        report.losses[0],
        report.final_loss,
        report.train_acc,
        report.avg_epoch_secs(),
        report.setup_secs
    );
    assert!(report.final_loss < report.losses[0]);

    println!("\nall stages green — three layers compose");
    Ok(())
}
