//! Quickstart: the paper's two-line integration story.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```
//!
//! Trains a 2-layer GCN on Zachary's karate club twice — once with stock
//! kernels (the "PyTorch" baseline) and once after `isplib::patch()` — and
//! shows that the results are identical while the kernels differ. This is
//! §3.6 of the paper: accelerate an existing training script by adding two
//! lines.

use isplib::prelude::*;

fn main() -> Result<()> {
    let dataset = isplib::data::karate_club();
    println!(
        "karate club: {} nodes, {} edges, {} classes",
        dataset.num_nodes(),
        dataset.num_edges(),
        dataset.num_classes
    );

    let cfg = TrainConfig { epochs: 60, hidden: 8, ..TrainConfig::default() };

    // --- stock kernels (iSpLib disengaged) --------------------------------
    unpatch();
    let mut trainer = Trainer::new(GnnModel::Gcn, Backend::NativeTrusted, cfg.clone(), &dataset)?;
    let stock = trainer.fit(&dataset)?;
    println!(
        "stock    : final_loss={:.4} train_acc={:.2} test_acc={:.2} avg_epoch={:.6}s",
        stock.final_loss,
        stock.train_acc,
        stock.test_acc,
        stock.avg_epoch_secs()
    );

    // --- the two lines -----------------------------------------------------
    isplib::patch(); // ① route every SpMM through the auto-tuned kernels
    let mut trainer = Trainer::new(GnnModel::Gcn, Backend::NativeTuned, cfg, &dataset)?;
    let tuned = trainer.fit(&dataset)?;
    isplib::unpatch(); // ② disengage when done
    println!(
        "isplib   : final_loss={:.4} train_acc={:.2} test_acc={:.2} avg_epoch={:.6}s",
        tuned.final_loss,
        tuned.train_acc,
        tuned.test_acc,
        tuned.avg_epoch_secs()
    );

    // drop-in replacement: identical learning outcome
    assert!((stock.final_loss - tuned.final_loss).abs() < 1e-2);
    println!(
        "speedup vs stock: {:.2}x (same accuracy — drop-in replacement)",
        stock.avg_epoch_secs() / tuned.avg_epoch_secs().max(1e-12)
    );
    Ok(())
}
