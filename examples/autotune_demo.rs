//! Auto-tuning demo: regenerate a paper-style "tuning graph" (Figure 2)
//! for one dataset on both modelled CPUs, then persist and reload the
//! tuning decision.
//!
//! ```text
//! cargo run --release --example autotune_demo
//! ```

use isplib::autotune::{
    render_ascii_chart, HardwareProfile, KernelRegistry, TuneConfig, Tuner, TuningDb,
};
use isplib::data::spec_by_name;
use isplib::error::Result;
use isplib::kernels::Semiring;

fn main() -> Result<()> {
    // the paper tunes "against a given dataset" — use scaled Reddit
    let spec = spec_by_name("reddit").expect("spec");
    let ds = spec.instantiate(512, 7)?;
    println!(
        "dataset {}: {} nodes, {} edges (scale 1/512 of the paper's)",
        ds.name,
        ds.num_nodes(),
        ds.num_edges()
    );

    for profile_name in ["intel-skylake", "amd-epyc", "host"] {
        let profile = HardwareProfile::named(profile_name)?;
        println!(
            "\nprofile {}: VLEN={} f32 lanes, candidate K-blocks {:?}, candidate K-tiles {:?}",
            profile.name,
            profile.vlen(),
            profile.candidate_kbs(),
            profile.candidate_kts()
        );
        let tuner = Tuner::with_config(
            profile,
            TuneConfig { ks: vec![16, 32, 64, 128, 256], reps: 3, warmup: 1, threads: 1 },
        );
        let report = tuner.sweep(&ds.name, &ds.adj)?;
        print!("{}", render_ascii_chart(&report));
    }

    // tune one embedding size, persist the decision, reload it
    let tuner = Tuner::with_config(HardwareProfile::named("host")?, TuneConfig::default());
    let registry = KernelRegistry::global();
    registry.set_patched(true);
    let mut db = TuningDb::default();
    let choice = tuner.tune(&ds.name, &ds.adj, 32, registry, &mut db)?;
    println!("\ntuned K=32 → {}", choice.label());

    let db_path = std::env::temp_dir().join("isplib_tuning_demo.json");
    db.save(&db_path)?;
    let reloaded = TuningDb::load(&db_path)?;
    println!(
        "persisted to {} and reloaded ({} entries); resolver now answers {}",
        db_path.display(),
        reloaded.entries.len(),
        registry.resolve(&ds.name, 32, Semiring::Sum).label()
    );
    std::fs::remove_file(&db_path).ok();
    registry.set_patched(false);
    Ok(())
}
