// Phase-level profile of one GCN training epoch (native stack).
use isplib::autodiff::{SpmmOperand, Tape};
use isplib::data::spec_by_name;
use isplib::dense::Dense;
use isplib::gnn::GnnModel;
use isplib::kernels::{spmm, KernelChoice, Semiring};
use isplib::sparse::NormKind;
use isplib::util::rng::Rng;
use std::time::Instant;

fn t<R>(label: &str, reps: usize, mut f: impl FnMut() -> R) -> f64 {
    let t0 = Instant::now();
    for _ in 0..reps { std::hint::black_box(f()); }
    let s = t0.elapsed().as_secs_f64() / reps as f64;
    println!("{label:<42} {s:>12.6}s");
    s
}

fn main() {
    let ds = spec_by_name("reddit").unwrap().instantiate(256, 7).unwrap();
    let n = ds.num_nodes();
    let (f, h, c) = (ds.feature_dim(), 32usize, ds.num_classes);
    println!("reddit/256: n={n} nnz={} f={f} h={h} c={c}", ds.num_edges());
    let a = NormKind::GcnSym.apply(&ds.adj).unwrap();
    let mut rng = Rng::seed_from_u64(1);
    let w0 = Dense::uniform(f, h, 0.1, &mut rng);
    let w1 = Dense::uniform(h, c, 0.1, &mut rng);
    let x = &ds.features;

    let xw = t("fwd: X@W0 (n*f*h GEMM)", 5, || x.matmul(&w0).unwrap());
    let xw0 = x.matmul(&w0).unwrap();
    let sp = t("fwd: spmm(A, XW0) K=h", 5, || spmm(&a, &xw0, Semiring::Sum, KernelChoice::Trusted, 1).unwrap());
    let h1 = spmm(&a, &xw0, Semiring::Sum, KernelChoice::Trusted, 1).unwrap();
    let hw = t("fwd: H@W1 (n*h*c GEMM)", 5, || h1.matmul(&w1).unwrap());
    let hw1 = h1.matmul(&w1).unwrap();
    let sp2 = t("fwd: spmm(A, HW1) K=c", 5, || spmm(&a, &hw1, Semiring::Sum, KernelChoice::Trusted, 1).unwrap());
    let tr = t("bwd extra: transpose(A) (uncached)", 5, || a.transpose());
    // backward GEMMs: dW0 = X^T @ G (f x h from n) — the big one
    let g = Dense::uniform(n, h, 0.1, &mut rng);
    let bg = t("bwd: X^T@G (f*n*h GEMM)", 5, || x.t_matmul(&g).unwrap());

    let operand = SpmmOperand::cached(a.clone(), "prof");
    let x_arc = std::sync::Arc::new(x.clone());
    let full = t("full train_step (tape)", 3, || {
        let mut tape = Tape::new(1);
        let xv = tape.input_no_grad(std::sync::Arc::clone(&x_arc));
        let w0v = tape.input(w0.clone());
        let w1v = tape.input(w1.clone());
        let h = tape.matmul(xv, w0v).unwrap();
        let h = tape.spmm(&operand, h).unwrap();
        let h = tape.relu(h).unwrap();
        let o = tape.matmul(h, w1v).unwrap();
        let o = tape.spmm(&operand, o).unwrap();
        let loss = tape.softmax_xent(o, &ds.labels, Some(&ds.train_mask)).unwrap();
        tape.backward(loss).unwrap();
        tape.value(loss).get(0,0)
    });
    println!("\nshare of full step: GEMMs {:.0}%, spmm {:.0}%, transpose-if-uncached {:.0}%",
        100.0*(xw+hw+bg)/full, 100.0*(sp+sp2)/full, 100.0*tr/full);
}
