//! The patch/unpatch workflow in detail (paper §3.6): registry state,
//! per-context bindings, the RAII decorator form, and proof that routing
//! changes which kernel runs without changing what it computes.
//!
//! ```text
//! cargo run --release --example patch_workflow
//! ```

use isplib::autotune::{HardwareProfile, KernelRegistry, RegistryEntry, TuneConfig, Tuner, TuningDb};
use isplib::coordinator::patch::PatchGuard;
use isplib::data::spec_by_name;
use isplib::dense::Dense;
use isplib::error::Result;
use isplib::kernels::{spmm, KernelChoice, Semiring};
use isplib::util::rng::Rng;

fn main() -> Result<()> {
    let ds = spec_by_name("amazon").expect("spec").instantiate(2048, 3)?;
    let registry = KernelRegistry::global();
    let mut rng = Rng::seed_from_u64(1);
    let x = Dense::uniform(ds.num_nodes(), 64, 1.0, &mut rng);

    // 1. Unpatched: every lookup resolves to the trusted kernel.
    isplib::unpatch();
    println!(
        "unpatched: resolve({}, K=64) = {}",
        ds.name,
        registry.resolve(&ds.name, 64, Semiring::Sum).label()
    );
    let y_stock = spmm(&ds.adj, &x, Semiring::Sum, registry.resolve(&ds.name, 64, Semiring::Sum), 1)?;

    // 2. Tune + patch: the tuner measures and binds the winner.
    let tuner = Tuner::with_config(HardwareProfile::named("host")?, TuneConfig::default());
    let mut db = TuningDb::default();
    isplib::patch();
    let choice = tuner.tune(&ds.name, &ds.adj, 64, registry, &mut db)?;
    println!("patched  : tuner bound {} for K=64", choice.label());
    let y_tuned = spmm(&ds.adj, &x, Semiring::Sum, registry.resolve(&ds.name, 64, Semiring::Sum), 1)?;
    assert!(y_tuned.allclose(&y_stock, 1e-4), "routing changed numerics!");
    println!("           identical output (max diff {:.2e})", y_tuned.max_abs_diff(&y_stock));

    // 3. Manual binding (the "user-defined operation" escape hatch).
    registry.bind(
        &ds.name,
        128,
        Semiring::Sum,
        RegistryEntry { choice: KernelChoice::Generated { kb: 32 }, speedup: 1.0 },
    );
    println!(
        "manual   : resolve({}, K=128) = {}",
        ds.name,
        registry.resolve(&ds.name, 128, Semiring::Sum).label()
    );

    // 4. Generated kernels never serve non-sum semirings — automatic fallback.
    println!(
        "fallback : resolve({}, K=64, mean) = {} (generated is sum-only, §3.4)",
        ds.name,
        registry.resolve(&ds.name, 64, Semiring::Mean).label()
    );

    // 5. unpatch() restores stock behaviour...
    isplib::unpatch();
    println!(
        "unpatched: resolve({}, K=64) = {}",
        ds.name,
        registry.resolve(&ds.name, 64, Semiring::Sum).label()
    );

    // 6. ...and the RAII guard is the decorator form.
    {
        let _guard = PatchGuard::new();
        println!(
            "guard    : inside scope, resolve = {}",
            registry.resolve(&ds.name, 64, Semiring::Sum).label()
        );
    }
    println!(
        "guard    : after scope,  resolve = {}",
        registry.resolve(&ds.name, 64, Semiring::Sum).label()
    );
    Ok(())
}
