#!/usr/bin/env bash
# Emit BENCH_kernels.json — the machine-readable kernel perf snapshot:
# per (graph, op, kernel, threads) cell a `format` field (csr / sell(C,σ)
# / sorted-csr) and `speedup` vs the trusted-CSR baseline, so the
# sparse-format axis is tracked PR-over-PR; a `plan` section with the
# fused-vs-unfused Spmm→Relu epilogue speedup per (graph, model) through
# the whole inference ExecutionPlan; a `fused_formats` section timing the
# fused epilogue against the unfused chain ON EACH sparse format (the
# tuner's joint format×fusion cells); an `inplace` section timing the
# copying `_into` dense ops against their in-place twins; plus the
# pool-vs-spawn per-call overhead microbenchmark; plus an `obs_overhead`
# section measuring the telemetry layer's hot-path cost — the same
# repeated small-SpMM loop with the obs registry off vs on, reported as
# `disabled_ns_per_call` / `enabled_ns_per_call` / `overhead_pct` (the
# disabled path is a single relaxed atomic load per dispatch, so the
# delta should be noise). Run from anywhere;
# extra args pass through to cargo bench. Set ISPLIB_BENCH_QUICK=1 for a
# fast smoke run.
#
# Checkpoint-write overhead is NOT measured here: durable saves
# (train --checkpoint-every) are epoch-granular cold-path I/O — two
# fsyncs plus a rename per epoch, amortised over a full epoch of SpMM
# work — and the per-epoch cost is already visible in the train report's
# `epoch_secs` when checkpointing is on vs off. If a checkpoint cadence
# ever gets hot enough to matter, add a `durable` section to this bench
# timing `durable::save` against a raw `fs::write` of the same payload.
set -euo pipefail
cd "$(dirname "$0")/../rust"

export ISPLIB_BENCH_OUT="${ISPLIB_BENCH_OUT:-$(cd .. && pwd)/BENCH_kernels.json}"
cargo bench --bench bench_kernels "$@"
echo "bench_kernels.sh: wrote ${ISPLIB_BENCH_OUT}"
