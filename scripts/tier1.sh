#!/usr/bin/env bash
# Tier-1 verify: release build, clippy with warnings promoted to errors,
# then the full test suite. CI and pre-merge both run exactly this.
set -euo pipefail
cd "$(dirname "$0")/../rust"

cargo build --release
cargo clippy --all-targets -- -D warnings
cargo test -q
