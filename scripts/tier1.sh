#!/usr/bin/env bash
# Tier-1 verify: release build, clippy with warnings promoted to errors,
# then the full test suite. CI and pre-merge both run exactly this.
# `--all-targets` keeps the serve/ subsystem and its integration tests
# (tests/serving_integration.rs) under the -D warnings gate, and the
# unfiltered `cargo test` run below executes them.
set -euo pipefail
cd "$(dirname "$0")/../rust"

cargo build --release
cargo clippy --all-targets -- -D warnings
cargo test -q
