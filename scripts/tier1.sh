#!/usr/bin/env bash
# Tier-1 verify: release build, clippy with warnings promoted to errors,
# then the full test suite — once with default features (failpoints
# compiled out to inline no-ops) and once with `--features failpoints`,
# which arms the fault-injection registry and runs the chaos suite
# (tests/chaos_integration.rs plus the in-crate chaos_tests modules).
# `--all-targets` keeps the serve/ subsystem and its integration tests
# (tests/serving_integration.rs) under the -D warnings gate, and the
# unfiltered `cargo test` runs below execute them.
#
# The obs suite (tests/obs_integration.rs + the obs:: unit tests) is also
# run explicitly in BOTH passes: the default pass guards the
# disabled-path/no-allocation contract and the Perfetto export, and the
# failpoints pass additionally checks that injected faults surface in the
# registry snapshot.
#
# The live-mutation suite (tests/mutation_integration.rs) likewise runs
# in BOTH passes: the default pass property-checks random delta / swap /
# request interleavings for bitwise equality against each request's
# admission-stamp reference, and the failpoints pass arms the
# serve.apply_delta / serve.hot_swap sites so mid-mutation faults are
# exercised (old epoch / old model must keep serving untouched).
#
# The sharding suite (tests/shard_integration.rs) runs in BOTH passes
# too: the default pass pins the bitwise-equality matrix (models ×
# formats × fusion × shard counts, values AND gradients, both executors
# plus the serving scheduler), and the failpoints pass additionally arms
# the kernels.halo_merge site inside the shard merge path.
#
# The durability suite (tests/durability_integration.rs) runs in BOTH
# passes as well: the default pass proves bitwise-resumable checkpoints
# (optimizers × models × checkpoint epochs) with the durable layer's
# failpoint sites compiled to no-ops, and the failpoints pass layers the
# crash-recovery chaos schedules on top — io.atomic_write / io.fsync /
# train.checkpoint faults torn mid-save must never leave state that
# fails to load, and every crash-restart must finish bitwise-identical
# to the uninterrupted run.
set -euo pipefail
cd "$(dirname "$0")/../rust"

cargo build --release
cargo clippy --all-targets -- -D warnings
cargo test -q
cargo test -q --test obs_integration
cargo test -q --test mutation_integration
cargo test -q --test shard_integration
cargo test -q --test durability_integration
cargo test -q --features failpoints
cargo test -q --features failpoints --test obs_integration
cargo test -q --features failpoints --test mutation_integration
cargo test -q --features failpoints --test shard_integration
cargo test -q --features failpoints --test durability_integration
