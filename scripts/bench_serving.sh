#!/usr/bin/env bash
# Emit BENCH_serving.json — the machine-readable serving snapshot: per
# session p50/p99 request latency (ns), batch occupancy, warm-start count,
# and the cross-session fairness spread, plus the subsystem's acceptance
# checks (batched == per-request bitwise, backprop cache untouched, shared
# pool job count). The underlying `isplib serve-bench` exits non-zero if
# any check fails, so this doubles as a serving smoke gate. Run from
# anywhere; extra args pass through (e.g. --scale 256 --requests 64 for a
# heavier run).
set -euo pipefail
cd "$(dirname "$0")/../rust"

OUT="${ISPLIB_SERVE_OUT:-$(cd .. && pwd)/BENCH_serving.json}"
cargo run --release --bin isplib -- serve-bench --out "$OUT" "$@"
echo "bench_serving.sh: wrote ${OUT}"
